"""Transaction objects and log-record framing for the Poplar engine.

The paper (§2) assumes each transaction produces a single log record holding
all of its writes.  A record here is framed as::

    [u32 length][u32 crc32-of-payload][payload]
    payload := [u64 ssn][u64 tid][u8 flags][u32 n_writes]
               n_writes * ([u32 key_len][key bytes][u32 val_len][val bytes])

``flags`` bit 0: HAS_READS — the transaction had a read set, i.e. it was
committed through the Qwr / CSN path and carries potential RAW dependencies.
Write-only (Qww) records may be replayed past RSNe during recovery (§5);
records with HAS_READS may not.

``flags`` bit 1: XSHARD — the record belongs to a cross-shard transaction
(`repro.shard`).  The payload then carries a dependency footer after the
writes::

    footer := [u32 n_parts] n_parts * ([u32 shard_id][u64 ssn])

listing every participating shard and the SSN the transaction holds there —
the explicit cross-shard WAW/RAW dependency edge.  The transaction's global
id (gtid) is the record's ``tid``, identical on every participant, so
sharded recovery can resolve a consistent cut: a cross-shard transaction is
replayed iff a record with its gtid is durable on *all* participants (see
``repro.shard.recovery``).

``flags`` bit 2: COMMAND — the record is *command-framed* (adaptive logging,
ROADMAP item 2): the per-write value slot carries the op's *parameter*
instead of the new tuple image, and the payload carries a command footer
after the write chain::

    cmd_footer := [u32 op_id][u32 n_deps]
                  n_deps * ([u32 key_len][key bytes][u64 observed_ssn])

``op_id`` names a deterministic operator in ``repro.core.command.COMMANDS``
(``new_value = op(old_value, param)``); the dep entries record, for each
written key, the SSN of the pre-image the transaction observed — the RAW
edge recovery must satisfy before re-executing the command.  The engine's
adaptive policy only emits command frames whose deps mirror the write chain
one-to-one (``n_deps == n_writes``, same keys, same order).  COMMAND and
XSHARD are mutually exclusive by policy (cross-shard records always carry
values); a frame with both bits set is treated as malformed.

The length+crc framing makes torn tail writes detectable: recovery truncates
the log at the first bad frame, which is exactly the paper's "buffer hole"
semantics at the device level.  Every decoder in this module walks frames
through one shared parser (:func:`_parse_frame`), so torn/corrupt/malformed
semantics cannot drift between the scalar, columnar, and streaming paths.
"""

from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

FLAG_HAS_READS = 0x01
FLAG_XSHARD = 0x02
FLAG_COMMAND = 0x04

_HDR = struct.Struct("<II")           # length, crc32
_PAYLOAD_FIXED = struct.Struct("<QQBI")  # ssn, tid, flags, n_writes
_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")
_XPART = struct.Struct("<IQ")         # shard_id, ssn (xdep footer entry)
_CMD_FIXED = struct.Struct("<II")     # op_id, n_deps (command footer prefix)


@dataclass
class Txn:
    """A transaction as seen by the logging subsystem."""

    tid: int
    # read set: list of (key, ssn observed at read time)
    read_set: List[Tuple[Any, int]] = field(default_factory=list)
    # write set: list of (key, new value bytes)
    write_set: List[Tuple[Any, bytes]] = field(default_factory=list)

    # Filled in by the engine:
    ssn: int = -1
    buffer_id: int = -1
    offset: int = -1          # logical offset of the record in its log buffer
    record: bytes = b""

    # cross-shard dependency edge (repro.shard): every participant shard and
    # the SSN this transaction holds there; None for single-shard records
    xdep: Optional[List[Tuple[int, int]]] = None

    # command framing (adaptive logging): when ``cmd_op`` is set the record
    # is emitted as FLAG_COMMAND — write_set values are op *params*, and
    # ``cmd_deps`` lists (key, observed pre-image ssn), mirroring write_set
    # order.  Mutually exclusive with ``xdep``.
    cmd_op: Optional[int] = None
    cmd_deps: Optional[List[Tuple[Any, int]]] = None

    # lifecycle timestamps (perf accounting)
    t_start: float = 0.0
    t_precommit: float = 0.0  # SSN allocated + record buffered ("pre-committed")
    t_commit: float = 0.0     # durably committed
    committed: bool = False
    aborted: bool = False

    @property
    def has_reads(self) -> bool:
        return bool(self.read_set)

    @property
    def write_only(self) -> bool:
        return not self.read_set

    def encode(self) -> bytes:
        """Serialize this transaction into a single framed log record."""
        flags = FLAG_HAS_READS if self.has_reads else 0
        if self.xdep is not None:
            flags |= FLAG_XSHARD
        if self.cmd_op is not None:
            if self.xdep is not None:
                raise ValueError("COMMAND and XSHARD are mutually exclusive")
            flags |= FLAG_COMMAND
        parts = [
            _PAYLOAD_FIXED.pack(self.ssn, self.tid, flags, len(self.write_set))
        ]
        for key, val in self.write_set:
            kb = key.encode() if isinstance(key, str) else bytes(key)
            parts.append(_U32.pack(len(kb)))
            parts.append(kb)
            parts.append(_U32.pack(len(val)))
            parts.append(val)
        if self.cmd_op is not None:
            deps = self.cmd_deps or []
            parts.append(_CMD_FIXED.pack(self.cmd_op, len(deps)))
            for key, dssn in deps:
                kb = key.encode() if isinstance(key, str) else bytes(key)
                parts.append(_U32.pack(len(kb)))
                parts.append(kb)
                parts.append(_U64.pack(dssn))
        if self.xdep is not None:
            parts.append(_U32.pack(len(self.xdep)))
            for shard_id, ssn in self.xdep:
                parts.append(_XPART.pack(shard_id, ssn))
        payload = b"".join(parts)
        self.record = _HDR.pack(len(payload), zlib.crc32(payload)) + payload
        return self.record


# the frame prefix of a record as an unaligned structured dtype: exactly
# _HDR ("<II") followed by _PAYLOAD_FIXED ("<QQBI"), 29 bytes
_FRAME_DTYPE = np.dtype(
    {
        "names": ["len", "crc", "ssn", "tid", "flags", "nw"],
        "formats": ["<u4", "<u4", "<u8", "<u8", "u1", "<u4"],
        "offsets": [0, 4, 8, 16, 24, 25],
        "itemsize": _HDR.size + _PAYLOAD_FIXED.size,
    }
)


def _scatter_ranges(starts: np.ndarray, width: int) -> np.ndarray:
    """Flat indices of ``n`` byte ranges ``[starts[i], starts[i]+width)``."""
    return (starts[:, None] + np.arange(width, dtype=np.int64)).ravel()


def encode_batch(txns: Sequence["Txn"]) -> Tuple[bytes, np.ndarray]:
    """Encode a batch of transactions into one contiguous framed blob —
    byte-identical to ``b"".join(t.encode() for t in txns)``, i.e. exactly
    the stream :func:`decode_columnar` reads back during recovery.

    The encode is columnar: every fixed-width field (frame headers, payload
    fixed parts, per-write key/value length prefixes) is computed as a numpy
    column and scattered into the output buffer in one fancy-index per
    column; the only per-item Python left is one memcpy per key/value blob
    and one ``zlib.crc32`` per record.  This is the encode half of the
    batched forward path: the caller reserves a contiguous region via
    :meth:`~repro.core.log_buffer.LogBuffer.reserve_batch` and fills it with
    the returned blob in one ring memcpy.

    Returns ``(blob, framed_lengths)``; ``framed_lengths[i]`` matches what
    ``Txn.encode`` would report for ``txns[i]``.
    """
    n = len(txns)
    if n == 0:
        return b"", np.empty(0, dtype=np.int64)

    kbs: List[bytes] = []
    vals: List[bytes] = []
    nw_l: List[int] = []
    ssn_l: List[int] = []
    tid_l: List[int] = []
    flag_l: List[int] = []
    op_l: List[int] = []
    dep_l: List[int] = []
    any_cmd = False
    for t in txns:
        nw_l.append(len(t.write_set))
        ssn_l.append(t.ssn)
        tid_l.append(t.tid)
        fl = FLAG_HAS_READS if t.read_set else 0
        if t.cmd_op is not None:
            fl |= FLAG_COMMAND
            any_cmd = True
            op_l.append(t.cmd_op)
            deps = t.cmd_deps or []
            if len(deps) != len(t.write_set):
                raise ValueError("cmd_deps must mirror write_set")
            dep_l.extend(d for _, d in deps)
        else:
            op_l.append(0)
            dep_l.extend(0 for _ in t.write_set)
        flag_l.append(fl)
        for key, val in t.write_set:
            kbs.append(key.encode() if isinstance(key, str) else bytes(key))
            vals.append(val)
    return encode_batch_columns(
        np.asarray(ssn_l, dtype=np.int64),
        np.asarray(tid_l, dtype=np.int64),
        np.asarray(flag_l, dtype=np.uint8),
        np.asarray(nw_l, dtype=np.int64),
        kbs,
        vals,
        cmd_op=np.asarray(op_l, dtype=np.int64) if any_cmd else None,
        cmd_dep_ssn=np.asarray(dep_l, dtype=np.int64) if any_cmd else None,
    )


def encode_batch_columns(
    ssn: np.ndarray,                 # (n,) per-record SSN
    tid: np.ndarray,                 # (n,) per-record tid
    flags: np.ndarray,               # (n,) uint8 flags (FLAG_HAS_READS)
    nw: np.ndarray,                  # (n,) writes per record
    kbs: Sequence[bytes],            # flattened key bytes, record-major
    vals: Sequence[bytes],           # flattened value bytes, record-major
    klen: Optional[np.ndarray] = None,
    vlen: Optional[np.ndarray] = None,
    cmd_op: Optional[np.ndarray] = None,
    cmd_dep_ssn: Optional[np.ndarray] = None,
) -> Tuple[bytes, np.ndarray]:
    """Columnar core of :func:`encode_batch`: frame a batch straight from
    arrays — the fully array-native entry used by the indexed batch pipeline
    (`repro.db.batch.BatchOCC.execute_indexed`), where keys/lengths come
    from the table's columns instead of per-``Txn`` objects.

    Mixed command/value batches: records whose ``flags`` carry
    ``FLAG_COMMAND`` gain the command footer.  ``cmd_op`` is the per-record
    op id and ``cmd_dep_ssn`` the per-*write* observed pre-image SSN (both
    only read where the owning record is command-framed); dep keys mirror
    the write chain, the policy invariant the footer format encodes."""
    n = len(ssn)
    if n == 0:
        return b"", np.empty(0, dtype=np.int64)
    frame = _FRAME_DTYPE.itemsize
    if klen is None:
        klen = np.fromiter(map(len, kbs), np.int64, len(kbs))
    if vlen is None:
        vlen = np.fromiter(map(len, vals), np.int64, len(vals))
    wlen = 8 + klen + vlen                       # framed bytes per write

    wstart = np.zeros(n + 1, dtype=np.int64)     # per-txn write-slice prefix
    np.cumsum(nw, out=wstart[1:])
    wcs = np.zeros(len(kbs) + 1, dtype=np.int64)
    np.cumsum(wlen, out=wcs[1:])
    chain = wcs[wstart[1:]] - wcs[wstart[:-1]]   # write-chain bytes per record
    is_cmd = (np.asarray(flags, dtype=np.uint8) & FLAG_COMMAND) != 0
    if is_cmd.any():
        if cmd_op is None or cmd_dep_ssn is None:
            raise ValueError("FLAG_COMMAND records need cmd_op/cmd_dep_ssn")
        kcs = np.zeros(len(kbs) + 1, dtype=np.int64)
        np.cumsum(klen, out=kcs[1:])
        rec_kbytes = kcs[wstart[1:]] - kcs[wstart[:-1]]
        foot = np.where(is_cmd, _CMD_FIXED.size + 12 * nw + rec_kbytes, 0)
    else:
        foot = 0
    plen = _PAYLOAD_FIXED.size + chain + foot
    lengths = _HDR.size + plen
    rec_off = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(lengths, out=rec_off[1:])
    out = np.zeros(int(rec_off[-1]), dtype=np.uint8)

    # frame prefixes (len/ssn/tid/flags/nw; crc patched after the blobs land)
    hdr = np.zeros(n, dtype=_FRAME_DTYPE)
    hdr["len"] = plen
    hdr["ssn"] = np.asarray(ssn, dtype=np.int64).view(np.uint64)
    hdr["tid"] = np.asarray(tid, dtype=np.int64).view(np.uint64)
    hdr["flags"] = flags
    hdr["nw"] = nw
    out[_scatter_ranges(rec_off[:-1], frame)] = hdr.view(np.uint8)

    if len(kbs):
        # absolute offset of each write's framed region
        intra = wcs[:-1] - np.repeat(wcs[wstart[:-1]], nw)
        woff = np.repeat(rec_off[:-1] + frame, nw) + intra
        out[_scatter_ranges(woff, 4)] = (
            klen.astype("<u4").view(np.uint8).reshape(-1, 4).ravel()
        )
        voff = woff + 4 + klen
        out[_scatter_ranges(voff, 4)] = (
            vlen.astype("<u4").view(np.uint8).reshape(-1, 4).ravel()
        )
        mv = memoryview(out)
        for o, ln, kb in zip((woff + 4).tolist(), klen.tolist(), kbs):
            mv[o : o + ln] = kb
        for o, ln, vb in zip((voff + 4).tolist(), vlen.tolist(), vals):
            mv[o : o + ln] = vb

    if is_cmd.any():
        # command footers: [u32 op][u32 n_deps] then one keyed dep per write
        cidx = np.flatnonzero(is_cmd)
        foot_off = rec_off[:-1] + frame + chain
        out[_scatter_ranges(foot_off[cidx], 4)] = (
            np.asarray(cmd_op, dtype=np.int64)[cidx]
            .astype("<u4").view(np.uint8).reshape(-1, 4).ravel()
        )
        out[_scatter_ranges(foot_off[cidx] + 4, 4)] = (
            nw[cidx].astype("<u4").view(np.uint8).reshape(-1, 4).ravel()
        )
        wmask = np.repeat(is_cmd, nw)
        if wmask.any():
            dlen = 12 + klen                     # framed bytes per dep entry
            dcs = np.zeros(len(kbs) + 1, dtype=np.int64)
            np.cumsum(dlen, out=dcs[1:])
            intra_dep = dcs[:-1] - np.repeat(dcs[wstart[:-1]], nw)
            dep_off = np.repeat(foot_off + _CMD_FIXED.size, nw) + intra_dep
            sel = np.flatnonzero(wmask)
            out[_scatter_ranges(dep_off[sel], 4)] = (
                klen[sel].astype("<u4").view(np.uint8).reshape(-1, 4).ravel()
            )
            mv = memoryview(out)
            offs = (dep_off + 4).tolist()
            lns = klen.tolist()
            for j in sel.tolist():
                mv[offs[j] : offs[j] + lns[j]] = kbs[j]
            ssn_off = dep_off + 4 + klen
            out[_scatter_ranges(ssn_off[sel], 8)] = (
                np.asarray(cmd_dep_ssn, dtype=np.int64)[sel]
                .astype("<u8").view(np.uint8).reshape(-1, 8).ravel()
            )

    # per-record CRC over the payload bytes, patched into the header column
    mv = memoryview(out)
    crc32 = zlib.crc32
    crcs = np.fromiter(
        (
            crc32(mv[p : p + ln])
            for p, ln in zip((rec_off[:-1] + _HDR.size).tolist(), plen.tolist())
        ),
        np.uint32,
        n,
    )
    out[_scatter_ranges(rec_off[:-1] + 4, 4)] = (
        crcs.astype("<u4").view(np.uint8).reshape(-1, 4).ravel()
    )
    return out.tobytes(), lengths


@dataclass
class LogRecord:
    """A decoded log record (recovery side)."""

    ssn: int
    tid: int
    has_reads: bool
    writes: List[Tuple[bytes, bytes]]
    # cross-shard dependency edge: [(shard_id, ssn), ...] over every
    # participant; None for single-shard records.  The gtid is ``tid``.
    xdep: Optional[List[Tuple[int, int]]] = None
    # command framing: op id + [(dep key, observed pre-image ssn), ...];
    # both None for value records.  When set, ``writes`` carries params.
    cmd_op: Optional[int] = None
    cmd_deps: Optional[List[Tuple[bytes, int]]] = None

    @property
    def write_only(self) -> bool:
        return not self.has_reads

    @property
    def is_command(self) -> bool:
        return self.cmd_op is not None


class Frame:
    """One fully parsed, validated log frame — the unit every decoder in
    this module consumes (see :func:`_parse_frame`)."""

    __slots__ = ("ssn", "tid", "flags", "n_writes", "keys", "vals", "klens",
                 "xdep", "cmd_op", "cmd_deps", "end")

    def __init__(self, ssn, tid, flags, n_writes, keys, vals, klens,
                 xdep, cmd_op, cmd_deps, end):
        self.ssn = ssn
        self.tid = tid
        self.flags = flags
        self.n_writes = n_writes
        self.keys = keys
        self.vals = vals
        self.klens = klens
        self.xdep = xdep
        self.cmd_op = cmd_op
        self.cmd_deps = cmd_deps
        self.end = end


def _parse_frame(buf: bytes, off: int, n: int) -> Optional[Frame]:
    """Parse and validate the frame starting at ``off``; ``None`` if it is
    torn (runs past ``n``), crc-corrupt, or malformed (write chain or footer
    out of bounds, COMMAND+XSHARD).  This is the *single* frame walk shared
    by :func:`decode_records` and :func:`decode_columnar_stream`, so the
    stop-at-first-bad-frame semantics are identical by construction."""
    if off + _HDR.size > n:
        return None
    length, crc = _HDR.unpack_from(buf, off)
    start = off + _HDR.size
    end = start + length
    if end > n:
        return None  # torn tail write
    payload = buf[start:end]
    if zlib.crc32(payload) != crc:
        return None  # corrupt frame: stop (holes never precede valid frames
        # on a device because segments flush sequentially)
    ssn, tid, flags, n_writes = _PAYLOAD_FIXED.unpack_from(payload, 0)
    pos = _PAYLOAD_FIXED.size
    keys: List[bytes] = []
    vals: List[bytes] = []
    klens: List[int] = []
    for _ in range(n_writes):
        if pos + 4 > length:
            return None
        (klen,) = _U32.unpack_from(payload, pos)
        pos += 4
        if pos + klen + 4 > length:
            return None
        key = payload[pos : pos + klen]
        pos += klen
        (vlen,) = _U32.unpack_from(payload, pos)
        pos += 4
        if pos + vlen > length:
            return None
        val = payload[pos : pos + vlen]
        pos += vlen
        keys.append(key)
        vals.append(val)
        klens.append(klen)
    cmd_op: Optional[int] = None
    cmd_deps: Optional[List[Tuple[bytes, int]]] = None
    if flags & FLAG_COMMAND:
        if flags & FLAG_XSHARD:
            return None  # the classes are exclusive; both bits == malformed
        if pos + _CMD_FIXED.size > length:
            return None
        cmd_op, n_deps = _CMD_FIXED.unpack_from(payload, pos)
        pos += _CMD_FIXED.size
        cmd_deps = []
        for _ in range(n_deps):
            if pos + 4 > length:
                return None
            (dklen,) = _U32.unpack_from(payload, pos)
            pos += 4
            if pos + dklen + 8 > length:
                return None
            dkey = payload[pos : pos + dklen]
            pos += dklen
            (dssn,) = _U64.unpack_from(payload, pos)
            pos += 8
            cmd_deps.append((dkey, dssn))
    xdep: Optional[List[Tuple[int, int]]] = None
    if flags & FLAG_XSHARD:
        xdep, pos = _decode_xdep(payload, pos, length)
        if xdep is None:
            return None
    return Frame(ssn, tid, flags, n_writes, keys, vals, klens,
                 xdep, cmd_op, cmd_deps, end)


def decode_records(buf: bytes) -> List[LogRecord]:
    """Decode a byte stream of framed records, truncating at the first torn
    or corrupt frame (paper §5: only fully durable records participate)."""
    out: List[LogRecord] = []
    off = 0
    n = len(buf)
    while True:
        fr = _parse_frame(buf, off, n)
        if fr is None:
            break
        out.append(
            LogRecord(
                ssn=fr.ssn,
                tid=fr.tid,
                has_reads=bool(fr.flags & FLAG_HAS_READS),
                writes=list(zip(fr.keys, fr.vals)),
                xdep=fr.xdep,
                cmd_op=fr.cmd_op,
                cmd_deps=fr.cmd_deps,
            )
        )
        off = fr.end
    return out


def _decode_xdep(
    payload: bytes, pos: int, length: int
) -> Tuple[Optional[List[Tuple[int, int]]], int]:
    """Parse the XSHARD dependency footer; ``(None, pos)`` on a bounds error
    (torn frame — the caller stops decoding, like any other malformed frame)."""
    if pos + 4 > length:
        return None, pos
    (n_parts,) = _U32.unpack_from(payload, pos)
    pos += 4
    if pos + n_parts * _XPART.size > length:
        return None, pos
    parts: List[Tuple[int, int]] = []
    for _ in range(n_parts):
        shard_id, ssn = _XPART.unpack_from(payload, pos)
        pos += _XPART.size
        parts.append((shard_id, ssn))
    return parts, pos


@dataclass
class ColumnarLog:
    """A decoded device log in columnar (struct-of-arrays) form.

    Per-record columns (length ``n_records``):

    * ``ssn``       — int64, monotone within one device log (flush order);
    * ``tid``       — int64;
    * ``has_reads`` — bool; write-only (Qww) records have ``has_reads=False``
      and may be replayed past RSNe, HAS_READS (Qwr) records may not;
    * ``n_writes``  — int32 writes carried by each record.

    Per-write columns (length ``n_writes.sum()``), flattened record-major so
    write ``j`` belongs to record ``wr_rec[j]``:

    * ``wr_rec``  — int64 owning-record index;
    * ``wr_klen`` — int64 true key length in bytes;
    * ``keys_fixed`` — the keys in a fixed-width numpy ``'S'`` array holding
      ``key + b"\\x01"`` NUL-padded to a multiple of 8 (so replay can
      reinterpret it as int64 words without copying).  The ``\\x01``
      terminator makes the padded cell an *exact*, self-delimiting key
      identity — raw NUL padding alone would make ``b"a"`` and ``b"a\\0"``
      compare equal under 'S' semantics.  Recover the original bytes by
      stripping trailing NULs and dropping the final byte (decode it with
      :meth:`fixed_to_key`);
    * ``keys`` / ``values`` — the raw bytes (variable length, Python lists;
      replay touches these only to materialize the winning entries).

    This is the decode format of the batched replay path: recovery never
    materializes per-record Python objects, it reduces these arrays directly
    (see :func:`repro.core.recovery.replay_columnar`).
    """

    ssn: np.ndarray
    tid: np.ndarray
    has_reads: np.ndarray
    n_writes: np.ndarray
    wr_rec: np.ndarray
    wr_klen: np.ndarray
    keys_fixed: np.ndarray
    keys: List[bytes]
    values: List[bytes]
    _values_obj: Optional[np.ndarray] = None
    # cross-shard dependency columns (``None`` when the log carries no
    # XSHARD records — the common case, and the shape every pre-shard
    # constructor produces).  ``x_rec[i]`` is the owning record index of the
    # i-th cross-shard record, ``xp_start`` the ``(len(x_rec)+1,)`` prefix
    # delimiting its participant slice of ``xp_shard``/``xp_ssn``.  The gtid
    # of ``x_rec[i]`` is ``tid[x_rec[i]]``.
    x_rec: Optional[np.ndarray] = None
    xp_start: Optional[np.ndarray] = None
    xp_shard: Optional[np.ndarray] = None
    xp_ssn: Optional[np.ndarray] = None
    # command columns (``None`` when the log carries no COMMAND records).
    # ``cmd_rec[i]`` is the owning record index of the i-th command record,
    # ``cmd_op[i]`` its registry op id, ``cmd_dep_start`` the
    # ``(len(cmd_rec)+1,)`` prefix delimiting its dep slice of
    # ``cmd_dep_key``/``cmd_dep_ssn`` (dep keys mirror the record's write
    # chain; the SSN is the observed pre-image version).  For command
    # records the ``values`` entries are op *params*, not tuple images.
    cmd_rec: Optional[np.ndarray] = None
    cmd_op: Optional[np.ndarray] = None
    cmd_dep_start: Optional[np.ndarray] = None
    cmd_dep_key: Optional[List[bytes]] = None
    cmd_dep_ssn: Optional[np.ndarray] = None

    @property
    def n_records(self) -> int:
        return len(self.ssn)

    @property
    def n_command(self) -> int:
        return 0 if self.cmd_rec is None else len(self.cmd_rec)

    @property
    def cmd_mask(self) -> np.ndarray:
        """Per-record bool: is record i command-framed?"""
        m = np.zeros(self.n_records, dtype=bool)
        if self.cmd_rec is not None:
            m[self.cmd_rec] = True
        return m

    @property
    def cmd_op_col(self) -> np.ndarray:
        """Per-record op id (-1 for value records)."""
        col = np.full(self.n_records, -1, dtype=np.int64)
        if self.cmd_rec is not None:
            col[self.cmd_rec] = self.cmd_op
        return col

    @staticmethod
    def encode_keys_fixed(keys: Sequence[bytes], klens: Sequence[int]) -> np.ndarray:
        """Build the sentinel-terminated fixed-width key array (see class
        docstring) for ``keys`` with known lengths ``klens``."""
        if not len(keys):
            return np.empty(0, dtype="S8")
        width = -(-(max(klens) + 1) // 8) * 8
        arr = np.asarray(keys, dtype=f"S{width}")
        u8 = arr.view(np.uint8).reshape(len(arr), width)
        u8[np.arange(len(arr)), np.asarray(klens)] = 1
        return arr

    @staticmethod
    def fixed_to_key(cell: bytes) -> bytes:
        """Invert the ``keys_fixed`` encoding for one (NUL-stripped) cell."""
        return cell[:-1]

    @property
    def values_obj(self) -> np.ndarray:
        """The values as an object ndarray (cached) — lets replay gather the
        winning payloads with one fancy-index instead of per-item list ops."""
        if self._values_obj is None:
            self._values_obj = np.fromiter(self.values, dtype=object, count=len(self.values))
        return self._values_obj

    @property
    def last_ssn(self) -> int:
        """SSN of the most recently durable record (device DSN frontier)."""
        return int(self.ssn[-1]) if len(self.ssn) else 0

    @property
    def wr_ssn(self) -> np.ndarray:
        """Per-write SSN (gathered from the owning record)."""
        return self.ssn[self.wr_rec]

    @property
    def wr_has_reads(self) -> np.ndarray:
        return self.has_reads[self.wr_rec]

    @property
    def n_xshard(self) -> int:
        return 0 if self.x_rec is None else len(self.x_rec)

    @staticmethod
    def concat(parts: Sequence["ColumnarLog"]) -> "ColumnarLog":
        """Concatenate decoded chunks of one log stream in arrival order —
        equivalent to decoding the concatenated bytes (incremental tailers
        decode only new frames and splice the chunks with this)."""
        parts = [p for p in parts if p.n_records]
        if not parts:
            return decode_columnar(b"")
        if len(parts) == 1:
            return parts[0]
        rec_off = np.cumsum([0] + [p.n_records for p in parts])
        keys: List[bytes] = []
        values: List[bytes] = []
        klens: List[int] = []
        x_rec: List[np.ndarray] = []
        xp_shard: List[np.ndarray] = []
        xp_ssn: List[np.ndarray] = []
        xp_start_parts: List[np.ndarray] = []
        xp_off = 0
        c_rec: List[np.ndarray] = []
        c_op: List[np.ndarray] = []
        c_dep_key: List[bytes] = []
        c_dep_ssn: List[np.ndarray] = []
        c_start_parts: List[np.ndarray] = []
        c_off = 0
        for i, p in enumerate(parts):
            keys.extend(p.keys)
            values.extend(p.values)
            klens.extend(p.wr_klen.tolist())
            if p.x_rec is not None:
                x_rec.append(p.x_rec + rec_off[i])
                xp_shard.append(p.xp_shard)
                xp_ssn.append(p.xp_ssn)
                xp_start_parts.append(p.xp_start[1:] + xp_off)
                xp_off += int(p.xp_start[-1])
            if p.cmd_rec is not None:
                c_rec.append(p.cmd_rec + rec_off[i])
                c_op.append(p.cmd_op)
                c_dep_key.extend(p.cmd_dep_key)
                c_dep_ssn.append(p.cmd_dep_ssn)
                c_start_parts.append(p.cmd_dep_start[1:] + c_off)
                c_off += int(p.cmd_dep_start[-1])
        has_x = bool(x_rec)
        has_c = bool(c_rec)
        return ColumnarLog(
            ssn=np.concatenate([p.ssn for p in parts]),
            tid=np.concatenate([p.tid for p in parts]),
            has_reads=np.concatenate([p.has_reads for p in parts]),
            n_writes=np.concatenate([p.n_writes for p in parts]),
            wr_rec=np.concatenate(
                [p.wr_rec + rec_off[i] for i, p in enumerate(parts)]
            ),
            wr_klen=np.asarray(klens, dtype=np.int64),
            keys_fixed=ColumnarLog.encode_keys_fixed(keys, klens),
            keys=keys,
            values=values,
            x_rec=np.concatenate(x_rec) if has_x else None,
            xp_start=np.concatenate([np.zeros(1, np.int64)] + xp_start_parts)
            if has_x else None,
            xp_shard=np.concatenate(xp_shard) if has_x else None,
            xp_ssn=np.concatenate(xp_ssn) if has_x else None,
            cmd_rec=np.concatenate(c_rec) if has_c else None,
            cmd_op=np.concatenate(c_op) if has_c else None,
            cmd_dep_start=np.concatenate(
                [np.zeros(1, np.int64)] + c_start_parts
            ) if has_c else None,
            cmd_dep_key=c_dep_key if has_c else None,
            cmd_dep_ssn=np.concatenate(c_dep_ssn) if has_c else None,
        )

    def to_records(self) -> List[LogRecord]:
        """Round-trip back to row objects (tests / scalar-oracle interop)."""
        xdeps: Dict[int, List[Tuple[int, int]]] = {}
        if self.x_rec is not None:
            for i, rec in enumerate(self.x_rec.tolist()):
                lo, hi = int(self.xp_start[i]), int(self.xp_start[i + 1])
                xdeps[rec] = list(
                    zip(self.xp_shard[lo:hi].tolist(), self.xp_ssn[lo:hi].tolist())
                )
        cmds: Dict[int, Tuple[int, List[Tuple[bytes, int]]]] = {}
        if self.cmd_rec is not None:
            for i, rec in enumerate(self.cmd_rec.tolist()):
                lo, hi = int(self.cmd_dep_start[i]), int(self.cmd_dep_start[i + 1])
                cmds[rec] = (
                    int(self.cmd_op[i]),
                    list(zip(self.cmd_dep_key[lo:hi],
                             self.cmd_dep_ssn[lo:hi].tolist())),
                )
        out: List[LogRecord] = []
        w = 0
        for i in range(self.n_records):
            nw = int(self.n_writes[i])
            op, deps = cmds.get(i, (None, None))
            out.append(
                LogRecord(
                    ssn=int(self.ssn[i]),
                    tid=int(self.tid[i]),
                    has_reads=bool(self.has_reads[i]),
                    writes=list(zip(self.keys[w : w + nw], self.values[w : w + nw])),
                    xdep=xdeps.get(i),
                    cmd_op=op,
                    cmd_deps=deps,
                )
            )
            w += nw
        return out


def decode_columnar(buf: bytes) -> ColumnarLog:
    """Columnar twin of :func:`decode_records`: one pass over the framed
    stream, truncating at the first torn or corrupt frame, emitting arrays
    instead of ``LogRecord`` objects.

    Same validation as the scalar decoder (length + crc32 per frame, bounds
    checks on every write) so torn-tail semantics are byte-identical.
    """
    return decode_columnar_stream(buf)[0]


def decode_columnar_stream(buf: bytes) -> Tuple[ColumnarLog, int]:
    """Incremental-framing variant of :func:`decode_columnar`: returns
    ``(log, consumed)`` where ``consumed`` is the byte offset of the first
    frame that did not decode — torn (runs past the end of ``buf``), corrupt
    (crc mismatch), or truncated mid-payload.

    This is the streaming contract of log shipping
    (`repro.replica.LogShipper`): on a *live* log a bad trailing frame just
    means the writer's append has not fully landed yet, so the tailer keeps
    the bytes from ``consumed`` on and retries once more bytes arrive — it
    never decodes a partial record.  A crash-recovery caller discards the
    remainder instead; both behaviours share this one decoder, so shipped
    and recovered torn-tail semantics are byte-identical.
    """
    ssns: List[int] = []
    tids: List[int] = []
    flags_l: List[bool] = []
    nw_l: List[int] = []
    wr_rec: List[int] = []
    klens: List[int] = []
    keys: List[bytes] = []
    values: List[bytes] = []
    x_rec: List[int] = []
    xp_shard: List[int] = []
    xp_ssn: List[int] = []
    xp_start: List[int] = [0]
    cmd_rec: List[int] = []
    cmd_op: List[int] = []
    cmd_dep_key: List[bytes] = []
    cmd_dep_ssn: List[int] = []
    cmd_dep_start: List[int] = [0]

    off = 0
    n = len(buf)
    rec_i = 0
    while True:
        fr = _parse_frame(buf, off, n)
        if fr is None:
            break  # torn, corrupt, or malformed: stop at the frame boundary
        keys.extend(fr.keys)
        values.extend(fr.vals)
        klens.extend(fr.klens)
        wr_rec.extend([rec_i] * fr.n_writes)
        if fr.xdep is not None:
            x_rec.append(rec_i)
            for shard_id, pssn in fr.xdep:
                xp_shard.append(shard_id)
                xp_ssn.append(pssn)
            xp_start.append(len(xp_shard))
        if fr.cmd_op is not None:
            cmd_rec.append(rec_i)
            cmd_op.append(fr.cmd_op)
            for dkey, dssn in fr.cmd_deps:
                cmd_dep_key.append(dkey)
                cmd_dep_ssn.append(dssn)
            cmd_dep_start.append(len(cmd_dep_key))
        ssns.append(fr.ssn)
        tids.append(fr.tid)
        flags_l.append(bool(fr.flags & FLAG_HAS_READS))
        nw_l.append(fr.n_writes)
        rec_i += 1
        off = fr.end

    return _columnar_from_lists(
        ssns, tids, flags_l, nw_l, wr_rec, klens, keys, values,
        x_rec, xp_start, xp_shard, xp_ssn,
        cmd_rec, cmd_op, cmd_dep_start, cmd_dep_key, cmd_dep_ssn,
    ), off


def _columnar_from_lists(
    ssns, tids, flags_l, nw_l, wr_rec, klens, keys, values,
    x_rec, xp_start, xp_shard, xp_ssn,
    cmd_rec=None, cmd_op=None, cmd_dep_start=None,
    cmd_dep_key=None, cmd_dep_ssn=None,
) -> ColumnarLog:
    has_cmd = bool(cmd_rec)
    return ColumnarLog(
        ssn=np.asarray(ssns, dtype=np.int64),
        tid=np.asarray(tids, dtype=np.int64),
        has_reads=np.asarray(flags_l, dtype=bool),
        n_writes=np.asarray(nw_l, dtype=np.int32),
        wr_rec=np.asarray(wr_rec, dtype=np.int64),
        wr_klen=np.asarray(klens, dtype=np.int64),
        keys_fixed=ColumnarLog.encode_keys_fixed(keys, klens),
        keys=keys,
        values=values,
        x_rec=np.asarray(x_rec, dtype=np.int64) if x_rec else None,
        xp_start=np.asarray(xp_start, dtype=np.int64) if x_rec else None,
        xp_shard=np.asarray(xp_shard, dtype=np.int64) if x_rec else None,
        xp_ssn=np.asarray(xp_ssn, dtype=np.int64) if x_rec else None,
        cmd_rec=np.asarray(cmd_rec, dtype=np.int64) if has_cmd else None,
        cmd_op=np.asarray(cmd_op, dtype=np.int64) if has_cmd else None,
        cmd_dep_start=np.asarray(cmd_dep_start, dtype=np.int64)
        if has_cmd else None,
        cmd_dep_key=list(cmd_dep_key) if has_cmd else None,
        cmd_dep_ssn=np.asarray(cmd_dep_ssn, dtype=np.int64)
        if has_cmd else None,
    )


def gather_u32(u8: np.ndarray, off: np.ndarray) -> np.ndarray:
    """Little-endian u32 values at arbitrary byte offsets of a uint8 view —
    the unaligned-field gather of the vectorized frame scan (int64 out)."""
    o = off.astype(np.int64, copy=False)
    return (
        u8[o].astype(np.int64)
        | u8[o + 1].astype(np.int64) << 8
        | u8[o + 2].astype(np.int64) << 16
        | u8[o + 3].astype(np.int64) << 24
    )


def gather_u64(u8: np.ndarray, off: np.ndarray) -> np.ndarray:
    """Little-endian u64 gather (int64 out — engine SSNs/tids are < 2^63)."""
    o = off.astype(np.int64, copy=False)
    acc = u8[o].astype(np.int64)
    for j in range(1, 8):
        acc |= u8[o + j].astype(np.int64) << (8 * j)
    return acc


def frame_scan(
    buf: bytes, skip_crc: bool = False
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Vectorized framing scan: offsets and payload lengths of every intact
    frame of ``buf``, truncated at the first torn or crc-corrupt frame —
    byte-identical boundaries to the scalar walk in
    :func:`decode_columnar_stream`, without per-record struct unpacking.

    The offset chase is run-speculative: consecutive records of one log
    buffer overwhelmingly share a framed length (fixed-size workloads
    produce exactly one run), so the scan guesses that frame ``i+1`` repeats
    frame ``i``'s length, verifies the whole run with one strided gather,
    and only falls back to stepping on a length change.  CRC validation is
    one C-speed ``zlib.crc32`` per frame over a zero-copy memoryview;
    ``skip_crc`` elides it entirely when the caller has already verified the
    blob wholesale against its seal-time segment crc (the manifest field a
    sealed segment carries — a whole-blob match implies every frame crc
    matches, since the frame crcs are part of the covered bytes).

    Returns ``(rec_off, plen, consumed)``: frame start offsets, payload
    lengths, and the byte offset of the first frame that did not decode.
    """
    u8 = np.frombuffer(buf, dtype=np.uint8)
    n = len(buf)
    hdr = _HDR.size
    parts: List[np.ndarray] = []
    off = 0
    while off + hdr <= n:
        (length,) = _U32.unpack_from(buf, off)
        stride = hdr + length
        if off + stride > n:
            break  # torn tail write
        max_run = (n - off) // stride
        if max_run <= 2:
            parts.append(np.asarray([off], dtype=np.int64))
            off += stride
            continue
        cand = off + np.arange(max_run, dtype=np.int64) * stride
        neq = gather_u32(u8, cand) != length
        run = int(np.argmax(neq)) if neq.any() else max_run
        parts.append(cand[:run])
        off += run * stride
    if not parts:
        return np.empty(0, np.int64), np.empty(0, np.int64), off
    rec_off = np.concatenate(parts)
    plen = gather_u32(u8, rec_off)
    if skip_crc:
        return rec_off, plen, off
    stored_crc = gather_u32(u8, rec_off + 4)
    mv = memoryview(buf)
    crc32 = zlib.crc32
    calc = np.fromiter(
        (
            crc32(mv[p : p + ln])
            for p, ln in zip((rec_off + hdr).tolist(), plen.tolist())
        ),
        np.int64,
        len(rec_off),
    )
    bad = np.flatnonzero(calc != stored_crc)
    if len(bad):
        good = int(bad[0])
        return rec_off[:good], plen[:good], int(rec_off[good])
    return rec_off, plen, off


def record_size(n_writes: int, key_bytes: int, val_bytes: int) -> int:
    """Size of a framed record for napkin math in benchmarks."""
    return _HDR.size + _PAYLOAD_FIXED.size + n_writes * (8 + key_bytes + val_bytes)
