"""Transaction objects and log-record framing for the Poplar engine.

The paper (§2) assumes each transaction produces a single log record holding
all of its writes.  A record here is framed as::

    [u32 length][u32 crc32-of-payload][payload]
    payload := [u64 ssn][u64 tid][u8 flags][u32 n_writes]
               n_writes * ([u32 key_len][key bytes][u32 val_len][val bytes])

``flags`` bit 0: HAS_READS — the transaction had a read set, i.e. it was
committed through the Qwr / CSN path and carries potential RAW dependencies.
Write-only (Qww) records may be replayed past RSNe during recovery (§5);
records with HAS_READS may not.

The length+crc framing makes torn tail writes detectable: recovery truncates
the log at the first bad frame, which is exactly the paper's "buffer hole"
semantics at the device level.
"""

from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

FLAG_HAS_READS = 0x01

_HDR = struct.Struct("<II")           # length, crc32
_PAYLOAD_FIXED = struct.Struct("<QQBI")  # ssn, tid, flags, n_writes
_U32 = struct.Struct("<I")


@dataclass
class Txn:
    """A transaction as seen by the logging subsystem."""

    tid: int
    # read set: list of (key, ssn observed at read time)
    read_set: List[Tuple[Any, int]] = field(default_factory=list)
    # write set: list of (key, new value bytes)
    write_set: List[Tuple[Any, bytes]] = field(default_factory=list)

    # Filled in by the engine:
    ssn: int = -1
    buffer_id: int = -1
    offset: int = -1          # logical offset of the record in its log buffer
    record: bytes = b""

    # lifecycle timestamps (perf accounting)
    t_start: float = 0.0
    t_precommit: float = 0.0  # SSN allocated + record buffered ("pre-committed")
    t_commit: float = 0.0     # durably committed
    committed: bool = False
    aborted: bool = False

    @property
    def has_reads(self) -> bool:
        return bool(self.read_set)

    @property
    def write_only(self) -> bool:
        return not self.read_set

    def encode(self) -> bytes:
        """Serialize this transaction into a single framed log record."""
        parts = [
            _PAYLOAD_FIXED.pack(
                self.ssn,
                self.tid,
                FLAG_HAS_READS if self.has_reads else 0,
                len(self.write_set),
            )
        ]
        for key, val in self.write_set:
            kb = key.encode() if isinstance(key, str) else bytes(key)
            parts.append(_U32.pack(len(kb)))
            parts.append(kb)
            parts.append(_U32.pack(len(val)))
            parts.append(val)
        payload = b"".join(parts)
        self.record = _HDR.pack(len(payload), zlib.crc32(payload)) + payload
        return self.record


@dataclass
class LogRecord:
    """A decoded log record (recovery side)."""

    ssn: int
    tid: int
    has_reads: bool
    writes: List[Tuple[bytes, bytes]]

    @property
    def write_only(self) -> bool:
        return not self.has_reads


def decode_records(buf: bytes) -> List[LogRecord]:
    """Decode a byte stream of framed records, truncating at the first torn
    or corrupt frame (paper §5: only fully durable records participate)."""
    out: List[LogRecord] = []
    off = 0
    n = len(buf)
    while off + _HDR.size <= n:
        length, crc = _HDR.unpack_from(buf, off)
        start = off + _HDR.size
        end = start + length
        if end > n:
            break  # torn tail write
        payload = buf[start:end]
        if zlib.crc32(payload) != crc:
            break  # corrupt frame: stop (holes never precede valid frames on
            # a device because segments flush sequentially)
        ssn, tid, flags, n_writes = _PAYLOAD_FIXED.unpack_from(payload, 0)
        pos = _PAYLOAD_FIXED.size
        writes: List[Tuple[bytes, bytes]] = []
        ok = True
        for _ in range(n_writes):
            if pos + 4 > length:
                ok = False
                break
            (klen,) = _U32.unpack_from(payload, pos)
            pos += 4
            key = payload[pos : pos + klen]
            pos += klen
            if pos + 4 > length:
                ok = False
                break
            (vlen,) = _U32.unpack_from(payload, pos)
            pos += 4
            val = payload[pos : pos + vlen]
            pos += vlen
            writes.append((key, val))
        if not ok:
            break
        out.append(LogRecord(ssn=ssn, tid=tid, has_reads=bool(flags & FLAG_HAS_READS), writes=writes))
        off = end
    return out


@dataclass
class ColumnarLog:
    """A decoded device log in columnar (struct-of-arrays) form.

    Per-record columns (length ``n_records``):

    * ``ssn``       — int64, monotone within one device log (flush order);
    * ``tid``       — int64;
    * ``has_reads`` — bool; write-only (Qww) records have ``has_reads=False``
      and may be replayed past RSNe, HAS_READS (Qwr) records may not;
    * ``n_writes``  — int32 writes carried by each record.

    Per-write columns (length ``n_writes.sum()``), flattened record-major so
    write ``j`` belongs to record ``wr_rec[j]``:

    * ``wr_rec``  — int64 owning-record index;
    * ``wr_klen`` — int64 true key length in bytes;
    * ``keys_fixed`` — the keys in a fixed-width numpy ``'S'`` array holding
      ``key + b"\\x01"`` NUL-padded to a multiple of 8 (so replay can
      reinterpret it as int64 words without copying).  The ``\\x01``
      terminator makes the padded cell an *exact*, self-delimiting key
      identity — raw NUL padding alone would make ``b"a"`` and ``b"a\\0"``
      compare equal under 'S' semantics.  Recover the original bytes by
      stripping trailing NULs and dropping the final byte (decode it with
      :meth:`fixed_to_key`);
    * ``keys`` / ``values`` — the raw bytes (variable length, Python lists;
      replay touches these only to materialize the winning entries).

    This is the decode format of the batched replay path: recovery never
    materializes per-record Python objects, it reduces these arrays directly
    (see :func:`repro.core.recovery.replay_columnar`).
    """

    ssn: np.ndarray
    tid: np.ndarray
    has_reads: np.ndarray
    n_writes: np.ndarray
    wr_rec: np.ndarray
    wr_klen: np.ndarray
    keys_fixed: np.ndarray
    keys: List[bytes]
    values: List[bytes]
    _values_obj: Optional[np.ndarray] = None

    @property
    def n_records(self) -> int:
        return len(self.ssn)

    @staticmethod
    def encode_keys_fixed(keys: Sequence[bytes], klens: Sequence[int]) -> np.ndarray:
        """Build the sentinel-terminated fixed-width key array (see class
        docstring) for ``keys`` with known lengths ``klens``."""
        if not len(keys):
            return np.empty(0, dtype="S8")
        width = -(-(max(klens) + 1) // 8) * 8
        arr = np.asarray(keys, dtype=f"S{width}")
        u8 = arr.view(np.uint8).reshape(len(arr), width)
        u8[np.arange(len(arr)), np.asarray(klens)] = 1
        return arr

    @staticmethod
    def fixed_to_key(cell: bytes) -> bytes:
        """Invert the ``keys_fixed`` encoding for one (NUL-stripped) cell."""
        return cell[:-1]

    @property
    def values_obj(self) -> np.ndarray:
        """The values as an object ndarray (cached) — lets replay gather the
        winning payloads with one fancy-index instead of per-item list ops."""
        if self._values_obj is None:
            self._values_obj = np.fromiter(self.values, dtype=object, count=len(self.values))
        return self._values_obj

    @property
    def last_ssn(self) -> int:
        """SSN of the most recently durable record (device DSN frontier)."""
        return int(self.ssn[-1]) if len(self.ssn) else 0

    @property
    def wr_ssn(self) -> np.ndarray:
        """Per-write SSN (gathered from the owning record)."""
        return self.ssn[self.wr_rec]

    @property
    def wr_has_reads(self) -> np.ndarray:
        return self.has_reads[self.wr_rec]

    def to_records(self) -> List[LogRecord]:
        """Round-trip back to row objects (tests / scalar-oracle interop)."""
        out: List[LogRecord] = []
        w = 0
        for i in range(self.n_records):
            nw = int(self.n_writes[i])
            out.append(
                LogRecord(
                    ssn=int(self.ssn[i]),
                    tid=int(self.tid[i]),
                    has_reads=bool(self.has_reads[i]),
                    writes=list(zip(self.keys[w : w + nw], self.values[w : w + nw])),
                )
            )
            w += nw
        return out


def decode_columnar(buf: bytes) -> ColumnarLog:
    """Columnar twin of :func:`decode_records`: one pass over the framed
    stream, truncating at the first torn or corrupt frame, emitting arrays
    instead of ``LogRecord`` objects.

    Same validation as the scalar decoder (length + crc32 per frame, bounds
    checks on every write) so torn-tail semantics are byte-identical.
    """
    ssns: List[int] = []
    tids: List[int] = []
    flags_l: List[bool] = []
    nw_l: List[int] = []
    wr_rec: List[int] = []
    klens: List[int] = []
    keys: List[bytes] = []
    values: List[bytes] = []

    off = 0
    n = len(buf)
    rec_i = 0
    while off + _HDR.size <= n:
        length, crc = _HDR.unpack_from(buf, off)
        start = off + _HDR.size
        end = start + length
        if end > n:
            break  # torn tail write
        payload = buf[start:end]
        if zlib.crc32(payload) != crc:
            break
        ssn, tid, flags, n_writes = _PAYLOAD_FIXED.unpack_from(payload, 0)
        pos = _PAYLOAD_FIXED.size
        ok = True
        wrote = 0
        for _ in range(n_writes):
            if pos + 4 > length:
                ok = False
                break
            (klen,) = _U32.unpack_from(payload, pos)
            pos += 4
            key = payload[pos : pos + klen]
            pos += klen
            if pos + 4 > length:
                ok = False
                break
            (vlen,) = _U32.unpack_from(payload, pos)
            pos += 4
            val = payload[pos : pos + vlen]
            pos += vlen
            keys.append(key)
            values.append(val)
            wr_rec.append(rec_i)
            klens.append(klen)
            wrote += 1
        if not ok:
            # drop the partial record's writes and stop at the bad frame
            del keys[len(keys) - wrote :]
            del values[len(values) - wrote :]
            del wr_rec[len(wr_rec) - wrote :]
            del klens[len(klens) - wrote :]
            break
        ssns.append(ssn)
        tids.append(tid)
        flags_l.append(bool(flags & FLAG_HAS_READS))
        nw_l.append(n_writes)
        rec_i += 1
        off = end

    return ColumnarLog(
        ssn=np.asarray(ssns, dtype=np.int64),
        tid=np.asarray(tids, dtype=np.int64),
        has_reads=np.asarray(flags_l, dtype=bool),
        n_writes=np.asarray(nw_l, dtype=np.int32),
        wr_rec=np.asarray(wr_rec, dtype=np.int64),
        wr_klen=np.asarray(klens, dtype=np.int64),
        keys_fixed=ColumnarLog.encode_keys_fixed(keys, klens),
        keys=keys,
        values=values,
    )


def record_size(n_writes: int, key_bytes: int, val_bytes: int) -> int:
    """Size of a framed record for napkin math in benchmarks."""
    return _HDR.size + _PAYLOAD_FIXED.size + n_writes * (8 + key_bytes + val_bytes)
