"""Transaction objects and log-record framing for the Poplar engine.

The paper (§2) assumes each transaction produces a single log record holding
all of its writes.  A record here is framed as::

    [u32 length][u32 crc32-of-payload][payload]
    payload := [u64 ssn][u64 tid][u8 flags][u32 n_writes]
               n_writes * ([u32 key_len][key bytes][u32 val_len][val bytes])

``flags`` bit 0: HAS_READS — the transaction had a read set, i.e. it was
committed through the Qwr / CSN path and carries potential RAW dependencies.
Write-only (Qww) records may be replayed past RSNe during recovery (§5);
records with HAS_READS may not.

The length+crc framing makes torn tail writes detectable: recovery truncates
the log at the first bad frame, which is exactly the paper's "buffer hole"
semantics at the device level.
"""

from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

FLAG_HAS_READS = 0x01

_HDR = struct.Struct("<II")           # length, crc32
_PAYLOAD_FIXED = struct.Struct("<QQBI")  # ssn, tid, flags, n_writes
_U32 = struct.Struct("<I")


@dataclass
class Txn:
    """A transaction as seen by the logging subsystem."""

    tid: int
    # read set: list of (key, ssn observed at read time)
    read_set: List[Tuple[Any, int]] = field(default_factory=list)
    # write set: list of (key, new value bytes)
    write_set: List[Tuple[Any, bytes]] = field(default_factory=list)

    # Filled in by the engine:
    ssn: int = -1
    buffer_id: int = -1
    offset: int = -1          # logical offset of the record in its log buffer
    record: bytes = b""

    # lifecycle timestamps (perf accounting)
    t_start: float = 0.0
    t_precommit: float = 0.0  # SSN allocated + record buffered ("pre-committed")
    t_commit: float = 0.0     # durably committed
    committed: bool = False
    aborted: bool = False

    @property
    def has_reads(self) -> bool:
        return bool(self.read_set)

    @property
    def write_only(self) -> bool:
        return not self.read_set

    def encode(self) -> bytes:
        """Serialize this transaction into a single framed log record."""
        parts = [
            _PAYLOAD_FIXED.pack(
                self.ssn,
                self.tid,
                FLAG_HAS_READS if self.has_reads else 0,
                len(self.write_set),
            )
        ]
        for key, val in self.write_set:
            kb = key.encode() if isinstance(key, str) else bytes(key)
            parts.append(_U32.pack(len(kb)))
            parts.append(kb)
            parts.append(_U32.pack(len(val)))
            parts.append(val)
        payload = b"".join(parts)
        self.record = _HDR.pack(len(payload), zlib.crc32(payload)) + payload
        return self.record


@dataclass
class LogRecord:
    """A decoded log record (recovery side)."""

    ssn: int
    tid: int
    has_reads: bool
    writes: List[Tuple[bytes, bytes]]

    @property
    def write_only(self) -> bool:
        return not self.has_reads


def decode_records(buf: bytes) -> List[LogRecord]:
    """Decode a byte stream of framed records, truncating at the first torn
    or corrupt frame (paper §5: only fully durable records participate)."""
    out: List[LogRecord] = []
    off = 0
    n = len(buf)
    while off + _HDR.size <= n:
        length, crc = _HDR.unpack_from(buf, off)
        start = off + _HDR.size
        end = start + length
        if end > n:
            break  # torn tail write
        payload = buf[start:end]
        if zlib.crc32(payload) != crc:
            break  # corrupt frame: stop (holes never precede valid frames on
            # a device because segments flush sequentially)
        ssn, tid, flags, n_writes = _PAYLOAD_FIXED.unpack_from(payload, 0)
        pos = _PAYLOAD_FIXED.size
        writes: List[Tuple[bytes, bytes]] = []
        ok = True
        for _ in range(n_writes):
            if pos + 4 > length:
                ok = False
                break
            (klen,) = _U32.unpack_from(payload, pos)
            pos += 4
            key = payload[pos : pos + klen]
            pos += klen
            if pos + 4 > length:
                ok = False
                break
            (vlen,) = _U32.unpack_from(payload, pos)
            pos += 4
            val = payload[pos : pos + vlen]
            pos += vlen
            writes.append((key, val))
        if not ok:
            break
        out.append(LogRecord(ssn=ssn, tid=tid, has_reads=bool(flags & FLAG_HAS_READS), writes=writes))
        off = end
    return out


def record_size(n_writes: int, key_bytes: int, val_bytes: int) -> int:
    """Size of a framed record for napkin math in benchmarks."""
    return _HDR.size + _PAYLOAD_FIXED.size + n_writes * (8 + key_bytes + val_bytes)
