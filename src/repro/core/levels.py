"""Constraint-level checkers (paper §3.1, Figure 1).

Given a recorded execution history — per-transaction sequence numbers, the
observed commit order, and the dependency graph — these predicates decide
whether a logging run satisfied:

* **Level 1, recoverability**: RAW ⇒ commit order; WAW ⇒ SSN order.
* **Level 2, rigorousness**:  every dependency (RAW, WAW, WAR) ⇒ both orders.
* **Level 3, sequentiality**: rigorous + totally ordered commits/SSNs for
  non-conflicting pairs.

They are used by the property tests (arbitrary interleavings through the
engines must stay at/above the engine's declared level) and by the crash
consistency oracle.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple


class Dep(Enum):
    RAW = "raw"   # Tj wrote x, Ti read Tj's update:   Cj < Ci required (L1)
    WAW = "waw"   # Tj wrote x, Ti overwrote it:       Lj < Li required (L1)
    WAR = "war"   # Tj read x, Ti overwrote it:        nothing required (L1)


@dataclass
class TxnInfo:
    tid: int
    ssn: int
    commit_seq: Optional[int]  # position in the commit order; None = never committed
    # dependencies: (predecessor tid, kind) — the predecessor happened first
    deps: List[Tuple[int, Dep]] = field(default_factory=list)


def check_recoverability(txns: Dict[int, TxnInfo]) -> List[str]:
    """Return a list of violations (empty ⇒ Level 1 holds)."""
    errs: List[str] = []
    for t in txns.values():
        for pred_tid, kind in t.deps:
            pred = txns.get(pred_tid)
            if pred is None:
                continue
            if kind is Dep.RAW:
                # Ti reads Tj's write ⇒ Cj ≺ Ci  (a committed reader requires
                # its writer committed earlier)
                if t.commit_seq is not None:
                    if pred.commit_seq is None or pred.commit_seq > t.commit_seq:
                        errs.append(
                            f"RAW violated: T{t.tid} (commit {t.commit_seq}) read "
                            f"T{pred_tid} (commit {pred.commit_seq})"
                        )
            elif kind is Dep.WAW:
                if not (pred.ssn < t.ssn):
                    errs.append(
                        f"WAW violated: T{t.tid} (ssn {t.ssn}) overwrote "
                        f"T{pred_tid} (ssn {pred.ssn})"
                    )
    return errs


def check_rigorousness(txns: Dict[int, TxnInfo]) -> List[str]:
    errs = check_recoverability(txns)
    for t in txns.values():
        for pred_tid, kind in t.deps:
            pred = txns.get(pred_tid)
            if pred is None:
                continue
            # every dependency ⇒ both orders
            if not (pred.ssn < t.ssn or (kind is Dep.WAR and pred.ssn <= t.ssn)):
                # WAR allows equality in Poplar's SSN (Fig 3: T4 gets the same
                # SSN as its WAR predecessor T3) — that is precisely what
                # rigorousness forbids and recoverability allows.
                errs.append(
                    f"{kind.value.upper()} ssn order violated: T{t.tid} ssn {t.ssn} "
                    f"vs pred T{pred_tid} ssn {pred.ssn}"
                )
            if t.commit_seq is not None and (
                pred.commit_seq is None or pred.commit_seq > t.commit_seq
            ):
                errs.append(
                    f"{kind.value.upper()} commit order violated: T{t.tid} vs T{pred_tid}"
                )
    return errs


def check_sequentiality(txns: Dict[int, TxnInfo]) -> List[str]:
    errs = check_rigorousness(txns)
    infos = [t for t in txns.values() if t.commit_seq is not None]
    infos.sort(key=lambda t: t.commit_seq)  # type: ignore[arg-type]
    for a, b in zip(infos, infos[1:]):
        if not (a.ssn < b.ssn):
            errs.append(
                f"total order violated: commit order T{a.tid} (ssn {a.ssn}) "
                f"then T{b.tid} (ssn {b.ssn})"
            )
    return errs


# ---------------------------------------------------------------------------
# Dependency derivation from an operation trace (used by property tests)
# ---------------------------------------------------------------------------

@dataclass
class Op:
    tid: int
    kind: str   # 'r' | 'w'
    key: str
    seq: int    # global order of the operation in the schedule


def derive_deps(ops: Sequence[Op]) -> Dict[int, List[Tuple[int, Dep]]]:
    """Derive RAW/WAW/WAR dependencies from a single-version operation trace
    (each read observes the latest preceding write)."""
    deps: Dict[int, List[Tuple[int, Dep]]] = {}
    last_write: Dict[str, Tuple[int, int]] = {}      # key -> (tid, seq)
    readers_since_write: Dict[str, Set[int]] = {}    # key -> tids reading cur version

    for op in sorted(ops, key=lambda o: o.seq):
        d = deps.setdefault(op.tid, [])
        if op.kind == "r":
            lw = last_write.get(op.key)
            if lw is not None and lw[0] != op.tid:
                d.append((lw[0], Dep.RAW))
            readers_since_write.setdefault(op.key, set()).add(op.tid)
        else:  # write
            lw = last_write.get(op.key)
            if lw is not None and lw[0] != op.tid:
                d.append((lw[0], Dep.WAW))
            for rt in readers_since_write.get(op.key, set()):
                if rt != op.tid:
                    d.append((rt, Dep.WAR))
            last_write[op.key] = (op.tid, op.seq)
            readers_since_write[op.key] = set()
    return deps
