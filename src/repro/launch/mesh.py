"""Production meshes.

Kept as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run sets
``--xla_force_host_platform_device_count=512`` before any jax import; tests
and benchmarks see the real single device unless they opt in themselves.
"""

from __future__ import annotations

import jax


def _mk(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; older jax defaults to Auto
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def mesh_context(mesh):
    """``jax.set_mesh(mesh)`` where available (newer jax); the classic
    ``with mesh:`` context (same named-axis semantics) otherwise."""
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_smoke_mesh(*, multi_pod: bool = False):
    """Tiny mesh for CPU tests (requires >=4 or >=8 host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)
