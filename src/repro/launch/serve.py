"""Serving driver: batched prefill + greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --reduced --batch 4 --prompt-len 32 --max-new 16

Reduced configs run on CPU; full configs use the same code the decode_32k /
long_500k dry-run cells compile for the production meshes.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None) -> int:
    from ..configs.base import reduced as make_reduced
    from ..configs.registry import get_config
    from ..models.api import build_model
    from ..models.serve_llm import ServeEngine

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params, cache_len=args.cache_len)

    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.vlm is not None:
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (args.batch, cfg.vlm.n_patches, cfg.d_model)), jnp.bfloat16)
    if cfg.enc_dec is not None:
        batch["frame_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (args.batch, cfg.enc_dec.enc_seq, cfg.d_model)), jnp.bfloat16)

    res = engine.generate(batch, max_new=args.max_new)
    print(f"[serve] arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.max_new}")
    print(f"  prefill {res.prefill_s*1e3:.1f} ms | decode {res.decode_s*1e3:.1f} ms "
          f"| {res.tokens_per_s:,.1f} tok/s")
    for i in range(min(args.batch, 2)):
        print(f"  sample {i}: {res.tokens[i].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
