"""End-to-end training driver with Poplar-journaled fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 100 --batch 8 --seq 256 --journal-dir /tmp/j

* restores from the journal automatically if one exists (checkpoint/restart);
* journals {params, opt, data cursor, step} every ``--save-every`` steps,
  asynchronously (training never blocks on IO);
* on the production mesh this runs under pjit with the same shardings as the
  dry-run (``--mesh production``); default is the local device count.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None) -> int:
    from ..configs.base import reduced as make_reduced
    from ..configs.registry import get_config
    from ..data.pipeline import DataConfig, TokenPipeline
    from ..journal import PoplarCheckpointManager, restore_latest, to_pytree
    from ..models.api import build_model
    from ..optim import adamw
    from ..train.step import make_train_step

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true", help="tiny same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--journal-dir", default=None)
    ap.add_argument("--lanes", type=int, default=2)
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--attn-impl", default=None)
    ap.add_argument("--mixer-impl", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--d-model", type=int, default=0, help="override reduced width")
    ap.add_argument("--n-layers", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        overrides = {}
        if args.d_model:
            overrides.update(d_model=args.d_model, head_dim=max(16, args.d_model // 4),
                             d_ff=args.d_model * 3)
        if args.n_layers:
            overrides["n_layers"] = args.n_layers
        cfg = make_reduced(cfg, **overrides)
    if args.attn_impl:
        cfg = dataclasses.replace(cfg, attn_impl=args.attn_impl)
    if args.mixer_impl:
        cfg = dataclasses.replace(cfg, mixer_impl=args.mixer_impl)

    model = build_model(cfg)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=max(args.steps, 100))
    step_fn = jax.jit(make_train_step(model, opt_cfg))

    data_cfg = DataConfig(vocab=cfg.vocab, batch=args.batch, seq_len=args.seq)

    params = model.init(jax.random.PRNGKey(0))
    opt_state = adamw.init(params, opt_cfg)
    start_step = 0
    pipe = TokenPipeline(data_cfg)

    mgr: Optional[PoplarCheckpointManager] = None
    if args.journal_dir:
        restored = restore_latest(args.journal_dir)
        if restored is not None:
            rstep, flat, meta = restored
            state_like = {"params": params, "opt": opt_state, "data": pipe.state()}
            tree = to_pytree(flat, state_like)
            params = jax.tree.map(jnp.asarray, tree["params"])
            opt_state = jax.tree.map(jnp.asarray, tree["opt"])
            pipe = TokenPipeline.restore(data_cfg, tree["data"])
            start_step = rstep + 1
            print(f"[restore] resumed from journaled step {rstep} "
                  f"(cursor={pipe.cursor}, meta={meta})", flush=True)
        mgr = PoplarCheckpointManager(args.journal_dir, n_lanes=args.lanes)

    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.2f}M "
          f"steps {start_step}..{args.steps} batch={args.batch}x{args.seq}", flush=True)

    t0 = time.perf_counter()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            tps = args.batch * args.seq * (step - start_step + 1) / (time.perf_counter() - t0)
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} tok/s {tps:,.0f}", flush=True)
        if mgr is not None and (step % args.save_every == 0 or step == args.steps - 1):
            mgr.save(step, {"params": params, "opt": opt_state, "data": pipe.state()},
                     {"loss": float(metrics["loss"])})
    if mgr is not None:
        mgr.wait_for_commit(args.steps - 1, timeout=120)
        print(f"[journal] last committed step: {mgr.last_committed_step()}", flush=True)
        mgr.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
