import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the real step function (train_step / prefill /
serve decode_step), lowers it against ShapeDtypeStruct stand-ins with the
production shardings, compiles it, and extracts:

  * ``memory_analysis()``   — per-device argument/output/temp bytes (fit proof)
  * ``cost_analysis()``     — per-device HLO FLOPs + bytes accessed
  * collective traffic     — parsed from the compiled HLO (per-device bytes)
  * roofline terms         — seconds on TPU v5e constants (see ROOFLINE)

Usage:
    python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --out benchmarks/results

Results are written as one JSON per cell into ``--out`` (default
``benchmarks/results``); ``benchmarks/roofline.py`` renders the table.
"""

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax

from .mesh import mesh_context
import jax.numpy as jnp

# TPU v5e constants (per chip)
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link (conservative single-link)


def build_cell(arch: str, shape_name: str, policy: str, *,
               attn_impl: Optional[str] = None,
               mixer_impl: Optional[str] = None,
               remat: str = "none",
               accum_steps: int = 1,
               moe_group: Optional[int] = None):
    """Returns (fn, args_sds, in_shardings, out_shardings, donate, meta)."""
    import dataclasses

    from ..configs.base import SHAPES
    from ..configs.registry import cell_applicable, get_config, input_specs
    from ..models.api import build_model
    from ..models.common import specs_to_sds
    from ..optim import adamw
    from ..parallel import axes as axes_mod
    from ..parallel import sharding as shd
    from ..train.step import make_train_step

    cfg = get_config(arch)
    if attn_impl:
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
    if mixer_impl:
        cfg = dataclasses.replace(cfg, mixer_impl=mixer_impl)
    if moe_group and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, group_size=moe_group)
        )
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return None, why, cfg, shape

    model = build_model(cfg, remat_policy=remat)
    pspecs = model.param_specs()
    params_sds = specs_to_sds(pspecs)
    batch_sds = input_specs(cfg, shape)

    def shardings(mesh):
        param_sh = shd.tree_shardings(pspecs, mesh, policy)
        batch_sh = shd.batch_shardings(batch_sds, mesh, policy)
        return param_sh, batch_sh

    if shape.phase == "train":
        opt_cfg = adamw.AdamWConfig(
            moment_dtype=jnp.bfloat16 if cfg.opt_moment_dtype == "bfloat16" else jnp.float32
        )
        opt_specs = adamw.opt_state_specs(pspecs, opt_cfg)
        opt_sds = specs_to_sds(opt_specs)
        step = make_train_step(model, opt_cfg, accum_steps=accum_steps)

        def make(mesh):
            param_sh, batch_sh = shardings(mesh)
            opt_sh = shd.tree_shardings(opt_specs, mesh, policy)
            rep = shd.replicated(mesh)
            metrics_sh = {"grad_norm": rep, "lr": rep, "loss": rep}

            def wrapped(params, opt_state, batch):
                with axes_mod.logical_context(mesh, policy):
                    return step(params, opt_state, batch)

            jitted = jax.jit(
                wrapped,
                in_shardings=(param_sh, opt_sh, batch_sh),
                out_shardings=(param_sh, opt_sh, metrics_sh),
                donate_argnums=(0, 1),
            )
            return jitted, (params_sds, opt_sds, batch_sds)

        meta = {"phase": "train", "fn": "train_step"}

    elif shape.phase == "prefill":
        cache_len = shape.seq_len

        def prefill(params, batch):
            return model.prefill(params, batch, cache_len)

        cache_specs = model.cache_specs(shape.global_batch, cache_len)

        def make(mesh):
            param_sh, batch_sh = shardings(mesh)
            cache_sh = shd.tree_shardings(cache_specs, mesh, policy)
            rep = shd.replicated(mesh)

            def wrapped(params, batch):
                with axes_mod.logical_context(mesh, policy):
                    return prefill(params, batch)

            jitted = jax.jit(
                wrapped,
                in_shardings=(param_sh, batch_sh),
                out_shardings=(rep, cache_sh),
            )
            return jitted, (params_sds, batch_sds)

        meta = {"phase": "prefill", "fn": "prefill"}

    else:  # decode
        cache_len = shape.seq_len
        cache_specs = model.cache_specs(shape.global_batch, cache_len)
        cache_sds = specs_to_sds(cache_specs)

        def serve_step(params, caches, batch):
            return model.decode_step(params, caches, batch["tokens"], batch["pos"])

        def make(mesh):
            param_sh, batch_sh = shardings(mesh)
            cache_sh = shd.tree_shardings(cache_specs, mesh, policy)
            rep = shd.replicated(mesh)

            def wrapped(params, caches, batch):
                with axes_mod.logical_context(mesh, policy):
                    return serve_step(params, caches, batch)

            jitted = jax.jit(
                wrapped,
                in_shardings=(param_sh, cache_sh, batch_sh),
                out_shardings=(rep, cache_sh),
                donate_argnums=(1,),
            )
            return jitted, (params_sds, cache_sds, batch_sds)

        meta = {"phase": "decode", "fn": "serve_step"}

    return make, meta, cfg, shape


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6·N_active·D train, 2·N_active·D inference."""
    n = cfg.n_active_params()
    mult = 6.0 if shape.phase == "train" else 2.0
    toks = shape.tokens if shape.phase != "decode" else shape.global_batch
    return mult * n * toks


def run_cell(arch: str, shape_name: str, mesh_kind: str, policy: str,
             out_dir: str, tag: str = "baseline", **kw) -> Dict[str, Any]:
    from .mesh import make_production_mesh
    from ..parallel import hlo_analysis

    t0 = time.time()
    made = build_cell(arch, shape_name, policy, **kw)
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "policy": policy, "tag": tag, **{k: v for k, v in kw.items() if v},
    }
    if made[0] is None:
        result["status"] = "skipped"
        result["reason"] = made[1]
        _write(out_dir, result, tag)
        return result

    make, meta, cfg, shape = made
    result.update(meta)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    try:
        with mesh_context(mesh):
            jitted, args = make(mesh)
            t1 = time.time()
            lowered = jitted.lower(*args)
            t2 = time.time()
            compiled = lowered.compile()
            t3 = time.time()

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        # Full HLO cost model with while-trip multiplication (XLA's own
        # cost_analysis counts loop bodies once — see hlo_analysis docstring).
        cost = hlo_analysis.analyze_hlo(hlo)
        colls = cost.collectives
        coll_traffic = cost.collective_traffic

        flops_dev = float(cost.dot_flops)
        bytes_dev = float(cost.traffic_bytes)
        mf = model_flops(cfg, shape)

        compute_s = flops_dev * n_chips / (n_chips * PEAK_FLOPS)
        memory_s = bytes_dev * n_chips / (n_chips * HBM_BW)
        # TPU-corrected memory term: excludes bf16<->f32 convert churn the
        # CPU backend inserts around every bf16 dot (absent on TPU/MXU)
        memory_tpu_s = (bytes_dev - cost.convert_traffic) / HBM_BW
        collective_s = coll_traffic / LINK_BW

        result.update({
            "status": "ok",
            "n_chips": n_chips,
            "lower_s": round(t2 - t1, 2),
            "compile_s": round(t3 - t2, 2),
            "hlo_bytes": len(hlo),
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_gb": round(
                    (ma.argument_size_in_bytes + ma.output_size_in_bytes
                     + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 1e9, 3),
            },
            "flops_per_device": flops_dev,
            "bytes_per_device": bytes_dev,
            "xla_cost_analysis": {
                "flops": float(ca.get("flops", 0.0)),
                "bytes accessed": float(ca.get("bytes accessed", 0.0)),
                "note": "loop bodies counted once by XLA; see flops_per_device",
            },
            "while_trips": cost.while_trips,
            "unknown_trip_whiles": cost.unknown_trip_whiles,
            "collectives": colls,
            "collective_traffic_per_device": coll_traffic,
            "collective_traffic_raw": cost.collective_traffic_raw,
            "tpu_dtype_correction": "f32 dot-partial ARs counted at bf16 width (CPU backend upcasts bf16 dots; jaxpr requests bf16 - see hlo_analysis)",
            "model_flops_global": mf,
            "model_flops_per_device": mf / n_chips,
            "useful_flop_ratio": round(mf / n_chips / flops_dev, 4) if flops_dev else None,
            "convert_traffic_per_device": cost.convert_traffic,
            "roofline": {
                "compute_s": compute_s,
                "memory_s": memory_s,
                "memory_tpu_s": memory_tpu_s,
                "collective_s": collective_s,
                "bottleneck": max(
                    ("compute", compute_s), ("memory", memory_tpu_s),
                    ("collective", collective_s), key=lambda kv: kv[1])[0],
                "step_s_lower_bound": max(compute_s, memory_tpu_s, collective_s),
                "step_s_lower_bound_raw": max(compute_s, memory_s, collective_s),
            },
        })
    except Exception as e:  # noqa: BLE001 - report the cell failure verbatim
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    result["total_s"] = round(time.time() - t0, 2)
    _write(out_dir, result, tag)
    return result


def _write(out_dir: str, result: Dict[str, Any], tag: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    name = f"{result['arch']}__{result['shape']}__{result['mesh']}__{tag}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(result, f, indent=1, default=str)


def main(argv=None) -> int:
    from ..configs.base import SHAPES
    from ..configs.registry import ARCH_NAMES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--policy", default=None, help="sharding policy (default: train/serve by phase)")
    ap.add_argument("--all", action="store_true", help="sweep all arch x shape cells")
    ap.add_argument("--out", default="benchmarks/results")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--attn-impl", default=None,
                    choices=[None, "masked_scan", "triangular", "flash"])
    ap.add_argument("--mixer-impl", default=None, choices=[None, "scan", "chunked"])
    ap.add_argument("--remat", default="none", choices=["none", "dots", "full"])
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--moe-group", type=int, default=None)
    args = ap.parse_args(argv)

    archs = list(ARCH_NAMES) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            phase = SHAPES[shape_name].phase
            policy = args.policy or ("train" if phase == "train" else "serve")
            for mesh_kind in meshes:
                r = run_cell(
                    arch, shape_name, mesh_kind, policy, args.out, tag=args.tag,
                    attn_impl=args.attn_impl, mixer_impl=args.mixer_impl,
                    remat=args.remat,
                    accum_steps=args.accum_steps, moe_group=args.moe_group,
                )
                line = {
                    "ok": lambda: (
                        f"OK   {arch:24s} {shape_name:12s} {mesh_kind:6s} "
                        f"compile={r['compile_s']:7.1f}s peak={r['memory']['peak_gb']:7.2f}GB "
                        f"bottleneck={r['roofline']['bottleneck']:10s} "
                        f"step>={r['roofline']['step_s_lower_bound']:.4f}s"
                    ),
                    "skipped": lambda: f"SKIP {arch:24s} {shape_name:12s} {mesh_kind:6s} {r['reason'][:60]}",
                    "error": lambda: f"FAIL {arch:24s} {shape_name:12s} {mesh_kind:6s} {r['error'][:120]}",
                }[r["status"]]()
                print(line, flush=True)
                if r["status"] == "error":
                    failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
