"""Threshold-based health monitors over the live system.

Each :class:`Monitor` reads its component directly (not the metrics
registry — monitors must work whether or not the registry is armed) and
yields structured :class:`HealthEvent`s when a threshold is crossed:

* :class:`ReplicaLagMonitor` — the replica-lag SLO: visible_ssn lag in
  SSNs (shipped-frontier spread the RSNe min-rule is holding back),
  seconds since the watermark last advanced, and ship backlog bytes;
* :class:`TruncationStallMonitor` — a consumer frontier pinning the
  truncator's safe point below the checkpoint RSN for several consecutive
  polls (disk grows without bound until the consumer catches up or is
  unregistered);
* :class:`SaturationMonitor` — serve-tier saturation: admission rejects in
  ``sustain`` consecutive polls (the queue-capacity backpressure signal),
  plus the backend's device-queue saturation flag as an early warning.

:class:`HealthMonitor` aggregates monitors and runs stepped
(:meth:`poll` from tests/drivers) or threaded (:meth:`start`), like every
other daemon in this repo.  Events are kept in a bounded history and
optionally pushed to a callback.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence

from .metrics import REGISTRY

WARN = "warn"
CRIT = "crit"


@dataclass
class HealthEvent:
    """One threshold crossing: what, how bad, and the numbers behind it."""

    kind: str                 # "replica_lag" | "truncation_stall" | "saturation"
    severity: str             # WARN | CRIT
    value: float              # the observed magnitude
    threshold: float          # the configured limit it crossed
    message: str
    t: float = field(default_factory=time.monotonic)

    def to_dict(self) -> Dict:
        return {
            "kind": self.kind, "severity": self.severity,
            "value": self.value, "threshold": self.threshold,
            "message": self.message, "t": self.t,
        }


class Monitor:
    """One health check; subclasses implement :meth:`check`."""

    def check(self) -> List[HealthEvent]:
        raise NotImplementedError


class ReplicaLagMonitor(Monitor):
    """SLO on a :class:`~repro.replica.replica.Replica`'s visibility lag."""

    def __init__(
        self,
        replica,
        max_lag_ssn: Optional[int] = None,
        max_lag_s: Optional[float] = None,
        max_backlog_bytes: Optional[int] = None,
    ):
        self.replica = replica
        self.max_lag_ssn = max_lag_ssn
        self.max_lag_s = max_lag_s
        self.max_backlog_bytes = max_backlog_bytes

    def check(self) -> List[HealthEvent]:
        out: List[HealthEvent] = []
        r = self.replica
        fr = r.shipped_frontiers()
        lag_ssn = (max(fr) if fr else 0) - r.visible_ssn()
        if self.max_lag_ssn is not None and lag_ssn > self.max_lag_ssn:
            out.append(HealthEvent(
                "replica_lag", CRIT, float(lag_ssn), float(self.max_lag_ssn),
                f"visible_ssn lags the shipped frontier by {lag_ssn} SSNs "
                f"(> {self.max_lag_ssn})",
            ))
        lag_s = time.monotonic() - getattr(r, "_w_advance_t", time.monotonic())
        if self.max_lag_s is not None and lag_s > self.max_lag_s:
            out.append(HealthEvent(
                "replica_lag", WARN, lag_s, self.max_lag_s,
                f"watermark has not advanced for {lag_s:.3f}s "
                f"(> {self.max_lag_s}s)",
            ))
        if self.max_backlog_bytes is not None:
            backlog = r.lag_bytes()
            if backlog > self.max_backlog_bytes:
                out.append(HealthEvent(
                    "replica_lag", WARN, float(backlog),
                    float(self.max_backlog_bytes),
                    f"ship backlog {backlog} bytes (> {self.max_backlog_bytes})",
                ))
        return out


class TruncationStallMonitor(Monitor):
    """A consumer frontier pinning the safe point below the checkpoint RSN
    on ``sustain`` consecutive checks (one slow poll is normal; a *sustained*
    pin means the log only grows)."""

    def __init__(self, truncator, max_pin_ssn: int = 0, sustain: int = 2):
        self.truncator = truncator
        self.max_pin_ssn = max_pin_ssn
        self.sustain = max(1, sustain)
        self._streak = 0

    def check(self) -> List[HealthEvent]:
        pin = self.truncator.stall_ssn()
        if pin > self.max_pin_ssn:
            self._streak += 1
        else:
            self._streak = 0
        if self._streak >= self.sustain:
            return [HealthEvent(
                "truncation_stall", CRIT, float(pin), float(self.max_pin_ssn),
                f"safe point pinned {pin} SSNs below the checkpoint RSN for "
                f"{self._streak} consecutive checks "
                f"(frontiers: {self.truncator.registry.frontiers()})",
            )]
        return []


class SaturationMonitor(Monitor):
    """Serve-tier saturation: sustained admission rejects (and, as an early
    warning, device-queue saturation reported by the backend)."""

    def __init__(self, scheduler, sustain: int = 3):
        self.scheduler = scheduler
        self.sustain = max(1, sustain)
        self._last_rejected = scheduler.n_rejected
        self._streak = 0

    def check(self) -> List[HealthEvent]:
        out: List[HealthEvent] = []
        cur = self.scheduler.n_rejected
        delta = cur - self._last_rejected
        self._last_rejected = cur
        if delta > 0:
            self._streak += 1
        else:
            self._streak = 0
        if self._streak >= self.sustain:
            out.append(HealthEvent(
                "saturation", CRIT, float(delta), 0.0,
                f"admission rejecting for {self._streak} consecutive checks "
                f"({delta} rejects since last check, "
                f"{cur} total) — queue capacity saturated",
            ))
        backend = getattr(self.scheduler, "backend", None)
        if backend is not None and getattr(backend, "saturated", None):
            try:
                if backend.saturated():
                    out.append(HealthEvent(
                        "saturation", WARN, 1.0, 0.0,
                        "backend device queues saturated "
                        f"(depths: {backend.queue_depths()})",
                    ))
            except Exception:
                pass  # a mid-teardown backend is not a health signal
        return out


class HealthMonitor:
    """Aggregates monitors; pollable or threaded.

    Every poll appends events to a bounded ``history``, mirrors an event
    counter into the metrics registry when it is armed, and pushes each
    event to ``on_event`` (if given).
    """

    def __init__(
        self,
        monitors: Sequence[Monitor],
        on_event: Optional[Callable[[HealthEvent], None]] = None,
        history: int = 256,
    ):
        self.monitors = list(monitors)
        self.on_event = on_event
        self.history: Deque[HealthEvent] = deque(maxlen=history)
        self.n_polls = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def poll(self) -> List[HealthEvent]:
        self.n_polls += 1
        events: List[HealthEvent] = []
        for m in self.monitors:
            events.extend(m.check())
        for ev in events:
            self.history.append(ev)
            if REGISTRY.enabled:
                REGISTRY.count(f"health.events.{ev.kind}")
            if self.on_event is not None:
                self.on_event(ev)
        return events

    # --- continuous operation (mirrors LogTruncator.start) ---------------
    def start(self, poll_interval: float = 50e-3) -> None:
        self._stop.clear()

        def _loop() -> None:
            while not self._stop.is_set():
                self.poll()
                time.sleep(poll_interval)

        self._thread = threading.Thread(target=_loop, daemon=True,
                                        name="health-monitor")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None
