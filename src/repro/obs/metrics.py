"""Process-local online metrics: counters, gauges, and a streaming
log2-bucketed quantile sketch.

This is the *runtime* counterpart of ``repro.trace.span``: where the tracer
captures per-span structure for post-hoc analysis, the registry keeps cheap
always-on aggregates (flush bytes per device, validate win rates, replica
lag, queue depth, ack-latency quantiles) that health monitors and the crash
flight recorder can snapshot at any moment.

The cost discipline is identical to the tracer's:

* a single module-level ``REGISTRY`` with an ``enabled`` bool;
* every hook in hot code is guarded by ``if REGISTRY.enabled:`` so the
  disarmed path is one attribute load and a false branch — measured
  zero-alloc by ``tests/test_obs.py`` with a tracemalloc filter pinned to
  this file, mirroring ``test_trace.py``;
* armed mutations take one short-lived lock per *event* (events are batch-
  or flush-granular, never per-key), keeping armed overhead under the 3%
  budget on the fig5 batch loop.

The quantile sketch is a fixed array of 64 power-of-two buckets indexed by
the binary exponent of the observed value: O(1) record, O(1) memory, no
stored samples, and any quantile is reconstructed to within the bucket
width (a factor of 2 relative error bound, typically much tighter because
the reported value is the geometric bucket midpoint).
"""

from __future__ import annotations

import math
import threading
from typing import Callable, Dict, Iterable, List, Optional, Sequence

import numpy as np

# Bucket b of the sketch covers values v with frexp-exponent b + _E_LO,
# i.e. v in [2^(b+_E_LO-1), 2^(b+_E_LO)).  With _E_LO = -40 the 64 buckets
# span ~9.1e-13 .. ~8.4e6 — sub-picosecond to ~97 days when observing
# seconds, and 1 .. 8.4M when observing integer lags.  Out-of-range values
# clamp to the edge buckets (their mass is still counted; min/max/sum stay
# exact).
_N_BUCKETS = 64
_E_LO = -40


class QuantileSketch:
    """Streaming histogram over power-of-two buckets.

    ``record`` is O(1) and allocation-free after construction; quantiles
    are interpolated from cumulative bucket counts.  ``count``/``total``/
    ``min``/``max`` are exact; a quantile is exact to within its bucket
    (ratio to the true sample quantile bounded by 2x either way).
    """

    __slots__ = ("counts", "count", "total", "vmin", "vmax")

    def __init__(self) -> None:
        self.counts = np.zeros(_N_BUCKETS, dtype=np.int64)
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def _bucket(self, v: float) -> int:
        if v <= 0.0:
            return 0
        e = math.frexp(v)[1] - _E_LO
        if e < 0:
            return 0
        if e >= _N_BUCKETS:
            return _N_BUCKETS - 1
        return e

    def record(self, v: float) -> None:
        v = float(v)
        self.counts[self._bucket(v)] += 1
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def record_many(self, values: Sequence[float]) -> None:
        """Vectorized ``record`` — one bincount for a whole batch."""
        a = np.asarray(values, dtype=np.float64)
        if a.size == 0:
            return
        _, e = np.frexp(np.maximum(a, 0.0))
        idx = np.clip(e - _E_LO, 0, _N_BUCKETS - 1)
        idx[a <= 0.0] = 0
        self.counts += np.bincount(idx, minlength=_N_BUCKETS)
        self.count += int(a.size)
        self.total += float(a.sum())
        self.vmin = min(self.vmin, float(a.min()))
        self.vmax = max(self.vmax, float(a.max()))

    @staticmethod
    def _bucket_mid(b: int) -> float:
        # geometric midpoint of [2^(e-1), 2^e) for e = b + _E_LO
        return math.ldexp(1.0, b + _E_LO) * (0.5 * math.sqrt(2.0))

    def quantile(self, q: float) -> float:
        """Value at quantile ``q`` in [0, 1]; 0.0 when empty."""
        if self.count == 0:
            return 0.0
        if q <= 0.0:
            return self.vmin          # the extremes are tracked exactly
        if q >= 1.0:
            return self.vmax
        rank = q * (self.count - 1)
        cum = 0
        for b in range(_N_BUCKETS):
            c = int(self.counts[b])
            if c == 0:
                continue
            cum += c
            if cum > rank:
                v = self._bucket_mid(b)
                # clamp to the exact observed range so p0/p100 are exact
                return min(max(v, self.vmin), self.vmax)
        return self.vmax

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def summary(self) -> Dict[str, float]:
        if self.count == 0:
            return {"count": 0}
        return {
            "count": int(self.count),
            "sum": float(self.total),
            "mean": self.mean(),
            "min": float(self.vmin),
            "max": float(self.vmax),
            "p50": self.quantile(0.50),
            "p90": self.quantile(0.90),
            "p99": self.quantile(0.99),
        }

    def reset(self) -> None:
        self.counts[:] = 0
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf


class Registry:
    """Named counters, gauges, and sketches behind one ``enabled`` switch.

    All mutators are safe to call whether or not the registry is enabled;
    the ``enabled`` guard lives at the *call sites* so that disarmed hot
    paths never enter this module at all (the zero-alloc contract).
    """

    def __init__(self) -> None:
        self.enabled = False
        self._lock = threading.Lock()
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.sketches: Dict[str, QuantileSketch] = {}
        self._callbacks: Dict[str, Callable[[], float]] = {}

    # --- mutators (armed hot path: one lock per batch-granular event) ----

    def count(self, name: str, delta: float = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + delta

    def gauge_set(self, name: str, value: float) -> None:
        with self._lock:
            self.gauges[name] = value

    def gauge_max(self, name: str, value: float) -> None:
        with self._lock:
            cur = self.gauges.get(name)
            if cur is None or value > cur:
                self.gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            sk = self.sketches.get(name)
            if sk is None:
                sk = self.sketches[name] = QuantileSketch()
            sk.record(value)

    def observe_many(self, name: str, values: Sequence[float]) -> None:
        with self._lock:
            sk = self.sketches.get(name)
            if sk is None:
                sk = self.sketches[name] = QuantileSketch()
            sk.record_many(values)

    # --- derived gauges (evaluated at snapshot time) ---------------------

    def register_callback(self, name: str, fn: Callable[[], float]) -> None:
        """Register a pull gauge, sampled on every ``snapshot()``."""
        with self._lock:
            self._callbacks[name] = fn

    def unregister_callback(self, name: str) -> None:
        with self._lock:
            self._callbacks.pop(name, None)

    # --- read side -------------------------------------------------------

    def sketch(self, name: str) -> QuantileSketch:
        with self._lock:
            sk = self.sketches.get(name)
            if sk is None:
                sk = self.sketches[name] = QuantileSketch()
            return sk

    def counter_value(self, name: str) -> float:
        with self._lock:
            return self.counters.get(name, 0)

    def gauge_value(self, name: str, default: float = 0.0) -> float:
        with self._lock:
            return self.gauges.get(name, default)

    def snapshot(self) -> Dict[str, Dict]:
        """Deterministically ordered point-in-time view of every metric.

        Pull-gauge callbacks are evaluated best-effort (a failing callback
        is reported as the string form of its exception rather than taking
        down a crash-path snapshot).
        """
        with self._lock:
            cbs = list(self._callbacks.items())
            counters = dict(sorted(self.counters.items()))
            gauges = dict(sorted(self.gauges.items()))
            sketches = {k: self.sketches[k].summary()
                        for k in sorted(self.sketches)}
        for name, fn in sorted(cbs):
            try:
                gauges[name] = fn()
            except Exception as e:  # crash-path snapshots must not raise
                gauges[name] = f"<callback error: {e!r}>"
        return {"counters": counters, "gauges": gauges, "sketches": sketches}

    def reset(self) -> None:
        """Drop every metric (callbacks stay registered)."""
        with self._lock:
            self.counters.clear()
            self.gauges.clear()
            self.sketches.clear()


#: process-wide registry, disarmed by default (hooks reduce to a bool load)
REGISTRY = Registry()


def enable(reset: bool = True) -> Registry:
    """Arm the process registry (optionally clearing prior metrics)."""
    if reset:
        REGISTRY.reset()
    REGISTRY.enabled = True
    return REGISTRY


def disable() -> Dict[str, Dict]:
    """Disarm the registry and return a final snapshot."""
    REGISTRY.enabled = False
    return REGISTRY.snapshot()
