"""Online observability: metrics registry, health monitors, crash flight
recorder, and post-crash recovery forensics.

This package is the *runtime* counterpart of the offline tracer in
``repro.trace``: the tracer reconstructs a dependency DAG after the fact,
while ``repro.obs`` keeps always-on aggregates a live system (or a crash
handler) can read right now.

* ``metrics`` — process-local ``REGISTRY`` of counters / gauges / log2
  quantile sketches, zero-alloc when disarmed;
* ``health`` — threshold monitors (replica-lag SLO, truncation stall,
  serve-tier saturation) yielding structured ``HealthEvent``s;
* ``flight`` — crash flight recorder snapshotting the registry and the
  tracer ring to ``*.flight.json`` on fault or signal;
* ``forensics`` — ``explain_recovery()``: a per-gtid kept/dropped verdict
  with the §5 rule that decided it, byte-checked against what
  ``recover()`` / ``recover_sharded()`` actually kept.

Instrumented hot modules (``core.engine``, ``db.batch``, ...) import
``repro.obs.metrics`` directly; everything heavier is resolved lazily here
(PEP 562) so arming a counter never drags the recovery stack into the
import graph.
"""

from .metrics import (  # noqa: F401
    QuantileSketch,
    Registry,
    REGISTRY,
    disable,
    enable,
)

_LAZY = {
    "HealthEvent": "health",
    "HealthMonitor": "health",
    "Monitor": "health",
    "ReplicaLagMonitor": "health",
    "SaturationMonitor": "health",
    "TruncationStallMonitor": "health",
    "FlightRecorder": "flight",
    "load_flight": "flight",
    "GtidVerdict": "forensics",
    "RecoveryExplanation": "forensics",
    "explain_recovery": "forensics",
    "explain_recovery_sharded": "forensics",
}

__all__ = [
    "QuantileSketch", "Registry", "REGISTRY", "disable", "enable",
    *sorted(_LAZY),
]


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(f".{mod}", __name__), name)
