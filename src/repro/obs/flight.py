"""Crash flight recorder: the last-known metrics + trace state on disk.

A :class:`FlightRecorder` snapshots the online metrics registry
(`repro.obs.metrics`) and the offline tracer ring (`repro.trace.span`) into
one JSON document and writes it to ``<path>.flight.json`` — on demand
(:meth:`dump`), on an unhandled exception, or on a termination signal
(:meth:`install`).  The dump is the forensic context for
``repro.obs.forensics.explain_recovery``: what the process was doing —
queue depths, flush rates, replica lag, the last ~64k trace spans — at the
moment it died, pinned next to the log bytes recovery will later decode.

Writes are atomic (tmp + rename): a crash *during* the flight dump leaves
either the previous dump or nothing, never a torn JSON.
"""

from __future__ import annotations

import json
import os
import signal as _signal
import sys
import time
from typing import Callable, Dict, List, Optional

from .metrics import REGISTRY, Registry

_SCHEMA = 1


def load_flight(path: str) -> Dict:
    """Load a ``*.flight.json`` dump written by :class:`FlightRecorder`."""
    with open(path) as f:
        return json.load(f)


class FlightRecorder:
    """Snapshot metrics + tracer ring to ``*.flight.json`` on fault/signal.

    ``path`` is the output file (conventionally ending ``.flight.json``;
    the suffix is appended when missing).  ``extra_fn`` optionally
    contributes an application payload (e.g. ``scheduler.stats()``) to every
    snapshot — it runs best-effort: a raising callback is recorded as an
    error string, never propagated from a crash path.
    """

    def __init__(
        self,
        path: str,
        registry: Optional[Registry] = None,
        tracer=None,
        extra_fn: Optional[Callable[[], Dict]] = None,
    ):
        if not path.endswith(".flight.json"):
            path += ".flight.json"
        self.path = path
        self.registry = registry if registry is not None else REGISTRY
        if tracer is None:
            from ..trace.span import TRACER as tracer
        self.tracer = tracer
        self.extra_fn = extra_fn
        self.n_dumps = 0
        self._installed_signals: Dict[int, object] = {}
        self._prev_excepthook: Optional[Callable] = None

    # --- snapshot + dump ---------------------------------------------------
    def snapshot(self, reason: str = "manual") -> Dict:
        """The full flight document (no IO)."""
        doc: Dict = {
            "schema": _SCHEMA,
            "reason": reason,
            "t_unix": time.time(),
            "pid": os.getpid(),
            "metrics": self.registry.snapshot(),
            "trace": self.tracer.dump().to_dict(),
        }
        if self.extra_fn is not None:
            try:
                doc["extra"] = self.extra_fn()
            except Exception as e:
                doc["extra"] = {"error": repr(e)}
        return doc

    def dump(self, reason: str = "manual") -> str:
        """Write the flight document atomically; returns the path."""
        doc = self.snapshot(reason)
        tmp = f"{self.path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(doc, f)
            f.write("\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)
        self.n_dumps += 1
        return self.path

    # --- fault / signal hooks ----------------------------------------------
    def install(
        self,
        signals: Optional[List[int]] = None,
        exceptions: bool = True,
    ) -> "FlightRecorder":
        """Arm the crash hooks: dump on the given signals (default SIGTERM,
        plus SIGUSR1 as a non-fatal snapshot trigger) and, with
        ``exceptions``, on any unhandled exception.  The previous handlers
        are chained, not replaced: after the dump a fatal signal still
        terminates the process and an exception still prints its traceback.
        Signal handlers only bind from the main thread; elsewhere the
        exception hook alone is installed.
        """
        if signals is None:
            signals = [_signal.SIGTERM]
            if hasattr(_signal, "SIGUSR1"):
                signals.append(_signal.SIGUSR1)
        for sig in signals:
            try:
                prev = _signal.signal(sig, self._on_signal)
            except ValueError:     # not the main thread
                break
            self._installed_signals[sig] = prev
        if exceptions:
            self._prev_excepthook = sys.excepthook
            sys.excepthook = self._on_exception
        return self

    def uninstall(self) -> None:
        for sig, prev in self._installed_signals.items():
            try:
                _signal.signal(sig, prev)
            except (ValueError, TypeError):
                pass
        self._installed_signals.clear()
        if self._prev_excepthook is not None:
            sys.excepthook = self._prev_excepthook
            self._prev_excepthook = None

    def _on_signal(self, signum, frame) -> None:
        try:
            self.dump(reason=f"signal:{_signal.Signals(signum).name}")
        finally:
            prev = self._installed_signals.get(signum)
            if callable(prev):
                prev(signum, frame)
            elif prev == _signal.SIG_DFL and signum != getattr(
                _signal, "SIGUSR1", None
            ):
                # re-deliver with the default disposition: the process dies
                # with the correct wait status, as if never intercepted
                _signal.signal(signum, _signal.SIG_DFL)
                _signal.raise_signal(signum)

    def _on_exception(self, exc_type, exc, tb) -> None:
        try:
            self.dump(reason=f"exception:{exc_type.__name__}")
        except Exception:
            pass                     # never mask the original failure
        hook = self._prev_excepthook or sys.__excepthook__
        hook(exc_type, exc, tb)
