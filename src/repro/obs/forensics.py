"""Post-crash forensics: a per-gtid verdict on what recovery kept and why.

``explain_recovery`` (and its sharded twin) re-decodes the surviving device
logs with the *same* machinery ``recover()`` uses — ``decode_columnar_stream``
for torn-tail framing, ``compute_rsne`` with truncation floors, and for the
sharded case the consistent-cut resolver from `repro.shard.recovery` — and
renders, for every gtid it can see, **kept or dropped plus the §5 rule that
decided it**:

* ``replayed``                          — durable and committed (write-only,
  or ``ssn <= RSNe``);
* ``above-rsne``                        — durable but RAW-carrying with
  ``ssn > RSNe``: provably unacknowledged, dropped;
* ``not-durable-on-all-participants``   — cross-shard record missing on at
  least one participant, dropped by the consistent cut;
* ``below-truncation-floor``            — dropped from the retained log, but
  every missing/failing copy sits at or below its shard's checkpoint RSN or
  truncation floor: the checkpoint image already carries its effects;
* ``torn-tail``                         — a partially flushed frame past the
  last decodable record (gtid recovered best-effort from the torn bytes);
* ``command-dep-unreplayable``          — a command-framed record (adaptive
  logging) whose observed pre-image SSN is neither in the retained log nor
  covered by the checkpoint image: ``recover()`` refuses to re-execute it
  (``CommandReplayError``) rather than guess a value.  A sound pipeline —
  adaptive policy framing plus the truncators' command-dep pin — never
  produces this verdict; seeing it means the log and checkpoint were
  manipulated out of band.

Because the verdicts come from the same cut, ``verify_bytes(state)`` can
replay *only* the kept gtids over the checkpoint image and demand byte
equality with what ``recover()``/``recover_sharded()`` actually produced —
the acceptance check the crash tests enforce.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.checkpoint import load_latest_checkpoint
from ..core.recovery import compute_rsne, device_ssn_floors, replay_columnar
from ..core.txn import ColumnarLog, decode_columnar_stream
from ..shard.recovery import _collect_cut_columnar, resolve_cut

RULE_REPLAYED = "replayed"
RULE_ABOVE_RSNE = "above-rsne"
RULE_NOT_DURABLE = "not-durable-on-all-participants"
RULE_BELOW_FLOOR = "below-truncation-floor"
RULE_TORN_TAIL = "torn-tail"
RULE_CMD_DEP = "command-dep-unreplayable"

# a torn tail needs the 8-byte frame header plus the leading (ssn, tid)
# qwords of the payload for a best-effort gtid parse
_TORN_MIN = 8 + 16
_NO_RSNE = int(np.iinfo(np.int64).max) // 2   # bypass the §5 guard in verify


@dataclass
class GtidVerdict:
    """One transaction's fate through recovery."""

    gtid: int
    kept: bool
    rule: str
    ssn: Dict[int, int]          # per-shard SSN ({0: ssn} for single-engine)
    has_reads: bool = False
    detail: str = ""

    def to_dict(self) -> Dict:
        return {
            "gtid": self.gtid, "kept": self.kept, "rule": self.rule,
            "ssn": {str(k): v for k, v in self.ssn.items()},
            "has_reads": self.has_reads, "detail": self.detail,
        }


@dataclass
class RecoveryExplanation:
    """All verdicts plus the watermarks they were judged against."""

    verdicts: Dict[int, GtidVerdict] = field(default_factory=dict)
    rsne: List[int] = field(default_factory=list)      # per shard
    rsns: List[int] = field(default_factory=list)      # per-shard ckpt RSN
    n_shards: int = 1
    torn: List[Dict] = field(default_factory=list)     # torn-tail sightings
    flight: Optional[Dict] = None                      # crash-context summary
    # decode products, retained so verify_bytes can replay the verdicts
    _shard_logs: List[List[ColumnarLog]] = field(
        default_factory=list, repr=False)
    _ckpt_data: List[Optional[Dict]] = field(default_factory=list, repr=False)

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for v in self.verdicts.values():
            out[v.rule] = out.get(v.rule, 0) + 1
        return out

    def to_dict(self) -> Dict:
        return {
            "n_shards": self.n_shards,
            "rsne": list(self.rsne),
            "rsns": list(self.rsns),
            "counts": self.counts(),
            "torn": list(self.torn),
            "flight": self.flight,
            "verdicts": [
                self.verdicts[g].to_dict() for g in sorted(self.verdicts)
            ],
        }

    def render(self) -> str:
        """Human-readable account, one line per gtid."""
        lines = [
            f"recovery forensics: {self.n_shards} shard(s), "
            f"RSNe={self.rsne}, checkpoint RSNs={self.rsns}"
        ]
        if self.flight:
            lines.append(
                f"crash context: {self.flight.get('reason', '?')} "
                f"(pid {self.flight.get('pid', '?')}, "
                f"t_unix {self.flight.get('t_unix', '?')})"
            )
        for g in sorted(self.verdicts):
            v = self.verdicts[g]
            fate = "KEPT " if v.kept else "DROP "
            ssn = ",".join(f"{q}:{s}" for q, s in sorted(v.ssn.items()))
            tail = f" — {v.detail}" if v.detail else ""
            lines.append(
                f"  gtid {g:>8}  {fate} {v.rule:<34} "
                f"ssn[{ssn}]{' R' if v.has_reads else '  '}{tail}"
            )
        kept = sum(1 for v in self.verdicts.values() if v.kept)
        by_rule = " ".join(
            f"{k}={n}" for k, n in sorted(self.counts().items()))
        lines.append(
            f"kept {kept}/{len(self.verdicts)} gtids ({by_rule})")
        return "\n".join(lines)

    # --- byte agreement with the recovery being explained ------------------
    def verify_bytes(self, state) -> Tuple[bool, List]:
        """Replay *only* the verdict-kept gtids over the checkpoint image and
        compare byte-for-byte with what recovery produced.  ``state`` is the
        :class:`~repro.core.recovery.RecoveredState` (single shard) or
        :class:`~repro.shard.recovery.ShardedRecoveredState`.

        Returns ``(agrees, mismatched_keys)``.
        """
        shard_states = state.shards if hasattr(state, "shards") else [state]
        assert len(shard_states) == len(self._shard_logs)
        bad: List = []
        for p, logs in enumerate(self._shard_logs):
            masks = [
                np.fromiter(
                    (self.verdicts[int(t)].kept for t in log.tid.tolist()),
                    dtype=bool, count=log.n_records,
                )
                for log in logs
            ]
            data, _, _ = replay_columnar(
                logs, _NO_RSNE, base=self._ckpt_data[p], record_mask=masks,
            )
            got = shard_states[p].data
            for k in set(data) | set(got):
                if data.get(k) != got.get(k):
                    bad.append((p, k, data.get(k), got.get(k)))
        return (not bad), bad


# --- decode helpers -----------------------------------------------------------

def _decode_device(d) -> Tuple[ColumnarLog, bytes]:
    """One device's surviving log + any torn-tail bytes past the last whole
    frame (segment-chained devices decode per sealed blob, like recovery)."""
    blobs = (
        d.read_segment_blobs() if hasattr(d, "read_segment_blobs")
        else [d.read_all()]
    )
    parts: List[ColumnarLog] = []
    torn = b""
    for blob in blobs:
        log, used = decode_columnar_stream(blob)
        parts.append(log)
        if used < len(blob):
            torn = blob[used:]
            break
    return parts[0] if len(parts) == 1 else ColumnarLog.concat(parts), torn


def _torn_fields(torn: bytes) -> Optional[Tuple[int, int]]:
    """Best-effort ``(ssn, gtid)`` from a torn frame (needs the header and
    the first 16 payload bytes to have hit the device)."""
    if len(torn) < _TORN_MIN:
        return None
    ssn, tid = struct.unpack_from("<QQ", torn, 8)
    return int(ssn), int(tid)


def _load_flight(flight) -> Optional[Dict]:
    if flight is None:
        return None
    if isinstance(flight, str):
        from .flight import load_flight
        flight = load_flight(flight)
    return {k: flight.get(k) for k in ("reason", "pid", "t_unix")}


def _ckpt(checkpoint_dir: Optional[str]) -> Tuple[Optional[Dict], int]:
    if checkpoint_dir is None:
        return None, 0
    ck = load_latest_checkpoint(checkpoint_dir, parallel=False)
    if ck is None:
        return None, 0
    return dict(ck.data), ck.rsn


def _local_verdict(
    shard: int, ssn: int, gtid: int, has_reads: bool, rsne: int, rsns: int,
) -> GtidVerdict:
    """The single-edge §5 rule: write-only replays whenever durable;
    RAW-carrying only with ``ssn <= RSNe``."""
    kept = (not has_reads) or ssn <= rsne
    if kept:
        rule, detail = RULE_REPLAYED, (
            "write-only: durable ⇒ committed" if not has_reads
            else f"ssn {ssn} <= RSNe {rsne}"
        )
    elif ssn <= rsns:
        rule = RULE_BELOW_FLOOR
        detail = (
            f"dropped from the log (ssn {ssn} > RSNe {rsne}) but the "
            f"checkpoint (RSNs {rsns}) already carries its effects"
        )
    else:
        rule = RULE_ABOVE_RSNE
        detail = f"has_reads and ssn {ssn} > RSNe {rsne}: never acknowledged"
    return GtidVerdict(gtid, kept, rule, {shard: ssn}, has_reads, detail)


def _command_dep_verdicts(
    ex: RecoveryExplanation,
    logs: Sequence[ColumnarLog],
    rsns: int,
    has_ckpt: bool,
) -> None:
    """Downgrade kept command records whose pre-image recovery cannot
    reach: a dep is replayable iff the checkpoint image covers it
    (``dep <= RSNs``, full-image checkpoints) or the dep's write is itself a
    kept record in the retained logs.  Anything else would make
    ``recover()`` raise ``CommandReplayError`` — surfaced here as the
    ``command-dep-unreplayable`` verdict."""
    if not any(log.n_command for log in logs):
        return
    # fixpoint: dropping one command strands any later command chained on
    # its write, so re-scan until no verdict flips (chains are short)
    changed = True
    while changed:
        changed = False
        written = set()
        for log in logs:
            if not len(log.wr_rec):
                continue
            kept = np.fromiter(
                (ex.verdicts[int(t)].kept for t in log.tid.tolist()),
                dtype=bool, count=log.n_records,
            )
            for j in np.flatnonzero(kept[log.wr_rec]).tolist():
                written.add((log.keys[j], int(log.wr_ssn[j])))
        for log in logs:
            if not log.n_command:
                continue
            for i, r in enumerate(log.cmd_rec.tolist()):
                v = ex.verdicts.get(int(log.tid[r]))
                if v is None or not v.kept:
                    continue
                lo, hi = (
                    int(log.cmd_dep_start[i]), int(log.cmd_dep_start[i + 1])
                )
                for dk, ds in zip(
                    log.cmd_dep_key[lo:hi], log.cmd_dep_ssn[lo:hi].tolist()
                ):
                    if (has_ckpt and ds <= rsns) or (dk, ds) in written:
                        continue
                    v.kept = False
                    v.rule = RULE_CMD_DEP
                    v.detail = (
                        f"command dep (key {dk!r}, ssn {ds}) is neither in "
                        f"the retained log nor covered by the checkpoint "
                        f"image (RSNs {rsns}): recovery refuses to re-execute"
                    )
                    changed = True
                    break


def _add_torn(ex: RecoveryExplanation, shard: int, dev: int, torn: bytes):
    if not torn:
        return
    row: Dict = {"shard": shard, "device": dev, "bytes": len(torn)}
    fields = _torn_fields(torn)
    if fields is not None:
        ssn, gtid = fields
        row["gtid"] = gtid
        ex.verdicts[gtid] = GtidVerdict(
            gtid, False, RULE_TORN_TAIL, {shard: ssn},
            detail=f"partial frame ({len(torn)} bytes) on device {dev}: "
                   "flush interrupted mid-record, never acknowledged",
        )
    ex.torn.append(row)


# --- entry points -------------------------------------------------------------

def explain_recovery(
    devices: Sequence,
    checkpoint_dir: Optional[str] = None,
    flight=None,
) -> RecoveryExplanation:
    """Per-gtid verdicts for a single-engine recovery over ``devices``.

    ``flight`` is an optional ``*.flight.json`` path (or loaded dict) whose
    crash context is folded into the rendering.
    """
    decoded = [_decode_device(d) for d in devices]
    logs = [log for log, _ in decoded]
    rsne = compute_rsne(logs, floors=device_ssn_floors(devices))
    ckpt_data, rsns = _ckpt(checkpoint_dir)

    ex = RecoveryExplanation(
        rsne=[rsne], rsns=[rsns], n_shards=1,
        flight=_load_flight(flight),
        _shard_logs=[logs], _ckpt_data=[ckpt_data],
    )
    for log in logs:
        for g, s, hr in zip(
            log.tid.tolist(), log.ssn.tolist(), log.has_reads.tolist()
        ):
            ex.verdicts[int(g)] = _local_verdict(
                0, int(s), int(g), bool(hr), rsne, rsns)
    _command_dep_verdicts(ex, logs, rsns, has_ckpt=ckpt_data is not None)
    for dev, (_, torn) in enumerate(decoded):
        _add_torn(ex, 0, dev, torn)
    return ex


def explain_recovery_sharded(
    shard_devices: Sequence[Sequence],
    checkpoint_dirs: Optional[Sequence[Optional[str]]] = None,
    flight=None,
) -> RecoveryExplanation:
    """Per-gtid verdicts for a sharded recovery, cross-shard records judged
    by the same consistent cut ``recover_sharded`` resolves."""
    n = len(shard_devices)
    decoded = [[_decode_device(d) for d in devs] for devs in shard_devices]
    shard_logs = [[log for log, _ in row] for row in decoded]
    rsne = [
        compute_rsne(logs, floors=device_ssn_floors(shard_devices[p]))
        for p, logs in enumerate(shard_logs)
    ]
    ckpt = [
        _ckpt(checkpoint_dirs[p] if checkpoint_dirs is not None else None)
        for p in range(n)
    ]
    rsns = [r for _, r in ckpt]
    # a fully truncated device also floors what "durable" can mean locally
    floor = [
        max([rsns[p]] + device_ssn_floors(shard_devices[p]))
        for p in range(n)
    ]

    durable, info = _collect_cut_columnar(shard_logs)
    keep = resolve_cut(durable, info, rsne)

    ex = RecoveryExplanation(
        rsne=rsne, rsns=rsns, n_shards=n,
        flight=_load_flight(flight),
        _shard_logs=shard_logs, _ckpt_data=[d for d, _ in ckpt],
    )

    # shard-local records: the single-edge rule
    for p, logs in enumerate(shard_logs):
        for log in logs:
            xset = (
                set(log.x_rec.tolist()) if log.x_rec is not None else set()
            )
            for i, (g, s, hr) in enumerate(zip(
                log.tid.tolist(), log.ssn.tolist(), log.has_reads.tolist()
            )):
                if i in xset:
                    continue
                ex.verdicts[int(g)] = _local_verdict(
                    p, int(s), int(g), bool(hr), rsne[p], rsns[p])

    # cross-shard records: the consistent cut's decision, explained
    for g, (parts, hr) in info.items():
        ssn_map = {int(q): int(s) for q, s in parts}
        kept = keep[g]
        if kept:
            rule = RULE_REPLAYED
            detail = (
                f"durable on all {len(parts)} participants"
                + ("" if not hr else " and ssn <= RSNe on every edge")
            )
        else:
            missing = [q for q, _ in parts if q not in durable.get(g, ())]
            if missing:
                if all(ssn_map[q] <= floor[q] for q in missing):
                    rule = RULE_BELOW_FLOOR
                    detail = (
                        f"missing on shard(s) {missing} but at/below their "
                        "truncation floors: the checkpoint carries it there"
                    )
                else:
                    rule = RULE_NOT_DURABLE
                    detail = (
                        f"no durable record on shard(s) {missing}: the "
                        "global commit never completed"
                    )
            else:
                failing = [
                    q for q, s in ssn_map.items() if s > rsne[q]]
                rule = RULE_ABOVE_RSNE
                detail = (
                    f"has_reads and ssn > RSNe on shard(s) {failing}: "
                    "never acknowledged"
                )
        ex.verdicts[int(g)] = GtidVerdict(
            int(g), kept, rule, ssn_map, bool(hr), detail)

    # command deps are shard-local (the policy value-frames x-records), so
    # each shard's coverage check sees only its own logs and checkpoint
    for p, logs in enumerate(shard_logs):
        _command_dep_verdicts(
            ex, logs, rsns[p], has_ckpt=ckpt[p][0] is not None
        )

    for p, row in enumerate(decoded):
        for dev, (_, torn) in enumerate(row):
            _add_torn(ex, p, dev, torn)
    return ex
