"""Logical-axis sharding rules -> PartitionSpec / NamedSharding resolution.

Every ParamSpec / cache-spec leaf carries logical axis names; a *policy*
maps each logical name to an ordered list of candidate mesh-axis tuples.
Resolution is greedy left-to-right over the leaf's dims with two constraints:

  * divisibility — a dim is sharded over a candidate only if the candidate's
    total mesh extent divides the dim;
  * exclusivity — a mesh axis is used at most once per leaf.

Candidates referencing mesh axes absent from the current mesh (e.g. "pod" on
the single-pod mesh) are skipped, so one policy serves both meshes.

Policies:

* ``train`` — batch over (pod, data); FSDP: the largest non-TP weight dim
  ("embed"/"vocab-alt") over (pod, data); TP over "model" (heads / mlp /
  vocab).  Optimizer moments inherit the param leaf's spec.
* ``serve`` — weights as train (FSDP+TP ⇒ per-layer all-gather: the
  *baseline* the roofline hillclimb starts from); caches over batch + heads.
* ``serve_2dtp`` — beyond-baseline: weight-stationary 2D tensor parallelism
  (contraction dims sharded over "data", output dims over "model") so decode
  moves activations, not weights.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.common import ParamSpec

Candidate = Tuple[str, ...]
Rules = Dict[str, List[Candidate]]

_TRAIN_RULES: Rules = {
    "vocab": [("model",)],
    "embed": [("pod", "data"), ("data",)],
    "embed2": [("model",)],
    "heads": [("model",)],
    "kv_heads": [("model",)],
    "head_dim": [("model",)],
    "mlp": [("model",)],
    "expert": [("model",), ("data",)],   # 8 experts vs 16-wide axes: falls through
    "layers": [],
    "batch": [("pod", "data"), ("data",)],
    "kv_seq": [("data",)],
}

_SERVE_RULES: Rules = dict(_TRAIN_RULES)

_SERVE_2DTP_RULES: Rules = {
    **_TRAIN_RULES,
    # weight-stationary: contraction dim over data, output dim over model
    "embed": [("data",)],
    "vocab": [("model",)],
    "batch": [("pod",), ()],   # tiny decode batches stay near-replicated
    "kv_seq": [("data",)],
}

POLICIES: Dict[str, Rules] = {
    "train": _TRAIN_RULES,
    "serve": _SERVE_RULES,
    "serve_2dtp": _SERVE_2DTP_RULES,
}


def resolve_pspec(
    shape: Sequence[int], logical: Sequence[Optional[str]], mesh: Mesh, rules: Rules
) -> P:
    used: set = set()
    parts: List[Any] = []
    for dim, name in zip(shape, logical):
        assigned = None
        if name is not None:
            for cand in rules.get(name, []):
                axes = tuple(cand)
                if not axes:
                    continue
                if any(a in used or a not in mesh.shape for a in axes):
                    continue
                extent = int(np.prod([mesh.shape[a] for a in axes]))
                if extent > 1 and dim % extent == 0:
                    assigned = axes if len(axes) > 1 else axes[0]
                    used.update(axes)
                    break
        parts.append(assigned)
    # trim trailing Nones for tidier specs
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def spec_sharding(spec: ParamSpec, mesh: Mesh, rules: Rules) -> NamedSharding:
    return NamedSharding(mesh, resolve_pspec(spec.shape, spec.logical, mesh, rules))


def tree_shardings(spec_tree, mesh: Mesh, policy: str = "train"):
    """Map a ParamSpec tree to a NamedSharding tree."""
    rules = POLICIES[policy]
    return jax.tree.map(
        lambda s: spec_sharding(s, mesh, rules),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def batch_shardings(input_spec_tree, mesh: Mesh, policy: str = "train"):
    """Shardings for model inputs: leading batch dim over (pod, data);
    scalars and trailing dims replicated."""
    rules = POLICIES[policy]

    def _one(sds: jax.ShapeDtypeStruct) -> NamedSharding:
        if len(sds.shape) == 0:
            return NamedSharding(mesh, P())
        logical = ["batch"] + [None] * (len(sds.shape) - 1)
        return NamedSharding(mesh, resolve_pspec(sds.shape, logical, mesh, rules))

    return jax.tree.map(_one, input_spec_tree)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
