"""Gradient compression for the DP all-reduce path.

Under ``pjit`` the gradient all-reduce is implicit (GSPMD inserts it), so
"compression" is expressed as quantize→dequantize around the reduction
boundary: gradients are quantized to int8 with per-chunk scales *before*
entering the optimizer, which (a) lets XLA perform the cross-replica
reduction on the int8/scale representation where profitable and (b) models
the accuracy contract of 8-bit gradient exchange.  An error-feedback
accumulator variant (`ef_quantize`) carries the quantization residual to
the next step — the standard trick that keeps convergence unaffected.

For explicit control (shard_map deployments), `compressed_psum` quantizes,
psums the int8 payload and rescales — this is the collective-bytes lever
reported in EXPERIMENTS §Perf for collective-bound cells.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

CHUNK = 2048


def quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-chunk symmetric int8 quantization.  Returns (q, scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % CHUNK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(-1, CHUNK)
    scale = jnp.max(jnp.abs(chunks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(chunks / jnp.maximum(scale, 1e-12)), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def fake_quantize(x: jax.Array) -> jax.Array:
    q, s = quantize(x)
    return dequantize(q, s, x.shape, x.dtype)


def fake_quantize_tree(tree):
    return jax.tree.map(fake_quantize, tree)


def ef_quantize(x: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback quantization: returns (quantized value, new residual)."""
    y = x.astype(jnp.float32) + err.astype(jnp.float32)
    yq = fake_quantize(y)
    return yq.astype(x.dtype), (y - yq).astype(err.dtype)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8 psum for shard_map code paths: quantize, reduce, rescale.

    Scales are reduced with max (conservative) so dequantization stays
    within range after summation.
    """
    q, s = quantize(x)
    n = jax.lax.psum(1, axis_name)
    s_max = jax.lax.pmax(s, axis_name)
    # requantize against the shared scale so the int8 payload is summable
    req = jnp.clip(
        jnp.round(q.astype(jnp.float32) * s / jnp.maximum(s_max, 1e-12)), -127, 127
    ).astype(jnp.int32)
    total = jax.lax.psum(req, axis_name)
    flat = (total.astype(jnp.float32) * s_max).reshape(-1)
    size = 1
    for d in x.shape:
        size *= d
    return flat[:size].reshape(x.shape).astype(x.dtype)
