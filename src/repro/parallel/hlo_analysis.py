"""HLO cost model: FLOPs / HBM traffic / collective traffic from compiled HLO.

Why not ``compiled.cost_analysis()``?  XLA's HloCostAnalysis counts a
``while`` body **once**, so any scan-over-layers model (ours) is undercounted
by ~n_layers; collective parsing has the same problem.  This module parses
the compiled module text, builds the call graph, and multiplies loop bodies
by their trip counts (recovered from the integer bound in the loop condition
— scans lower to ``i < N`` with constant N).

Conventions (documented in EXPERIMENTS §Roofline):

* **FLOPs** — dot/convolution FLOPs only (2·M·N·K), the MFU convention;
  elementwise/transcendental ops are excluded.
* **HBM traffic** — per instruction: result bytes + operand bytes, counted at
  fusion boundaries (fusion internals don't touch HBM); parameters /
  constants / tuples / GTEs / bitcasts are free.
* **Collectives** — result bytes × effective-traffic multiplier
  (all-gather 1.0, all-reduce 2.0, reduce-scatter 1.0, all-to-all 1.0,
  collective-permute 1.0), per device.

All numbers are **per device** (the module is the per-device SPMD program).
"""

from __future__ import annotations

import re
from collections import Counter, defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_COLL_MULT = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[\w\.\-]+)\s*=\s*(?P<rtype>\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>[\w\-]+)\((?P<args>.*?)\)(?P<rest>.*)$"
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w\.\-]+)\s*\((?P<params>.*)\)\s*->\s*.*\{\s*$")
_PARAM_RE = re.compile(r"([\w\.\-]+):\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONST_INT_RE = re.compile(r"=\s*[su]\d+\[\]\s*constant\((\d+)\)")

_NO_TRAFFIC_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "after-all", "iota", "partition-id", "replica-id",
}

_SKIP_CALLED = {
    "reduce", "reduce-window", "scatter", "select-and-scatter", "sort", "map",
    "all-reduce", "reduce-scatter", "all-reduce-start",
}


def _shape_elems(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


def _type_bytes(t: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(t):
        total += _shape_elems(dims) * _DTYPE_BYTES.get(dtype, 4)
    return total


def _type_dims(t: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(t)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class _Instr:
    name: str
    rtype: str
    op: str
    args: str
    rest: str


@dataclass
class _Comp:
    name: str
    instrs: List[_Instr] = field(default_factory=list)
    const_ints: List[int] = field(default_factory=list)


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _parse_module(text: str) -> Tuple[Dict[str, _Comp], Dict[str, str], Optional[str]]:
    comps: Dict[str, _Comp] = {}
    shapes: Dict[str, str] = {}     # instruction/param name -> result type str
    entry: Optional[str] = None
    cur: Optional[_Comp] = None
    for line in text.splitlines():
        if "/*" in line:
            line = _COMMENT_RE.sub("", line)
        if cur is None or (line and not line[0].isspace() and line.rstrip().endswith("{")):
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = _Comp(m.group("name"))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
                for pname, ptype in _PARAM_RE.findall(m.group("params")):
                    shapes[pname] = ptype
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            ins = _Instr(mi.group("name"), mi.group("rtype"), mi.group("op"),
                         mi.group("args"), mi.group("rest"))
            cur.instrs.append(ins)
            shapes[ins.name] = ins.rtype
        mc = _CONST_INT_RE.search(line)
        if mc:
            cur.const_ints.append(int(mc.group(1)))
    return comps, shapes, entry


@dataclass
class HloCost:
    dot_flops: float = 0.0
    traffic_bytes: float = 0.0
    convert_traffic: float = 0.0          # dtype-convert churn (CPU f32-dot artifact)
    collective_traffic: float = 0.0
    collective_traffic_raw: float = 0.0   # without the TPU-dtype correction
    collectives: Dict[str, Dict[str, float]] = field(default_factory=dict)
    unknown_trip_whiles: int = 0
    while_trips: List[int] = field(default_factory=list)

    def merge_scaled(self, other: "HloCost", k: float) -> None:
        self.dot_flops += other.dot_flops * k
        self.traffic_bytes += other.traffic_bytes * k
        self.convert_traffic += other.convert_traffic * k
        self.collective_traffic += other.collective_traffic * k
        self.collective_traffic_raw += other.collective_traffic_raw * k
        for op, st in other.collectives.items():
            mine = self.collectives.setdefault(
                op, {"count": 0.0, "bytes": 0.0, "traffic": 0.0, "traffic_raw": 0.0})
            for f in ("count", "bytes", "traffic", "traffic_raw"):
                mine[f] += st.get(f, 0.0) * k
        self.unknown_trip_whiles += other.unknown_trip_whiles
        self.while_trips.extend(other.while_trips)


_ATTR_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_ATTR_TO_APPLY = re.compile(r"to_apply=%?([\w\.\-]+)")
_ATTR_BODY = re.compile(r"body=%?([\w\.\-]+)")
_ATTR_COND = re.compile(r"condition=%?([\w\.\-]+)")
_ATTR_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_ATTR_TRIP = re.compile(r'known_trip_count=\{[^}]*?[":]+(\d+)')


def _dot_flops(ins: _Instr, shapes: Dict[str, str]) -> float:
    out_elems = 0
    for dtype, dims in _SHAPE_RE.findall(ins.rtype):
        out_elems += _shape_elems(dims)
    mk = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    contract = 1
    if mk:
        ops = _OPERAND_RE.findall(ins.args)
        lhs_type = shapes.get(ops[0]) if ops else None
        # inline operand types take precedence if present
        inline = _SHAPE_RE.search(ins.args)
        dims = _type_dims(lhs_type) if lhs_type else None
        if dims is None and inline:
            dims = _type_dims(inline.group(0))
        if dims is not None and mk.group(1):
            for idx in mk.group(1).split(","):
                i = int(idx)
                if i < len(dims):
                    contract *= dims[i]
    return 2.0 * out_elems * contract


def _comp_cost(
    comp: _Comp,
    comps: Dict[str, _Comp],
    shapes: Dict[str, str],
    memo: Dict[str, HloCost],
    tpu_dtype_correction: bool = True,
) -> HloCost:
    if comp.name in memo:
        return memo[comp.name]
    memo[comp.name] = HloCost()  # break cycles defensively
    cost = HloCost()
    for ins in comp.instrs:
        op = ins.op
        # --- flops ---
        if op == "dot":
            cost.dot_flops += _dot_flops(ins, shapes)
        elif op == "convolution":
            # rough: 2 * out_elems * kernel_elems (no grouped-conv refinement)
            out_elems = sum(_shape_elems(d) for _, d in _SHAPE_RE.findall(ins.rtype))
            ops = _OPERAND_RE.findall(ins.args)
            k_elems = 1
            if len(ops) > 1 and ops[1] in shapes:
                dims = _type_dims(shapes[ops[1]]) or []
                for d in dims[:-1]:
                    k_elems *= d
            cost.dot_flops += 2.0 * out_elems * k_elems

        # --- traffic ---
        if op not in _NO_TRAFFIC_OPS and op not in ("while", "fusion"):
            refs = _OPERAND_RE.findall(ins.args)
            if op in ("dynamic-slice", "gather", "slice"):
                # indexed reads touch only the slice, not the whole operand
                b = 2 * _type_bytes(ins.rtype)
            elif op == "dynamic-update-slice":
                upd = refs[1] if len(refs) > 1 else None
                ub = _type_bytes(shapes.get(upd, "f32[]")) if upd else 0
                b = 2 * ub
            elif op == "scatter":
                upd = refs[2] if len(refs) > 2 else None
                ub = _type_bytes(shapes.get(upd, "f32[]")) if upd else 0
                b = 2 * ub
            else:
                b = _type_bytes(ins.rtype)
                for name in refs:
                    if name in shapes:
                        b += _type_bytes(shapes[name])
            cost.traffic_bytes += b
            if op == "convert":
                # bf16<->f32 conversion churn: XLA CPU upcasts every bf16 dot
                # to f32 (the jaxpr requests bf16 / MXU semantics), inserting
                # converts that do not exist in the TPU program.  Tracked so
                # the roofline can report a TPU-corrected memory term.
                cost.convert_traffic += b

        # --- collectives ---
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLL_MULT:
            rb = _type_bytes(ins.rtype)
            if op.endswith("-start"):
                rb = rb // 2 or rb  # start ops carry (operand, result) tuples
            # TPU-dtype correction: XLA *CPU* force-upcasts bf16 dots to f32,
            # so partial-sum all-reduces appear at f32 width even though the
            # jaxpr requested preferred_element_type=bf16 (MXU semantics).
            # Count those at bf16 width — metadata ties the AR to its
            # dot_general.  Raw (uncorrected) bytes are kept separately.
            rb_corr = rb
            if (
                tpu_dtype_correction
                and "dot_general" in ins.rest
                and "f32[" in ins.rtype
                and "bf16[" not in ins.rtype
            ):
                rb_corr = rb // 2
            st = cost.collectives.setdefault(base, {"count": 0.0, "bytes": 0.0, "traffic": 0.0, "traffic_raw": 0.0})
            st["count"] += 1
            st["bytes"] += rb_corr
            st["traffic"] += rb_corr * _COLL_MULT[base]
            st["traffic_raw"] += rb * _COLL_MULT[base]
            cost.collective_traffic += rb_corr * _COLL_MULT[base]
            cost.collective_traffic_raw += rb * _COLL_MULT[base]

        # --- called computations ---
        if op == "while":
            body = _ATTR_BODY.search(ins.rest)
            cond = _ATTR_COND.search(ins.rest)
            trip_m = _ATTR_TRIP.search(ins.rest)
            trip = int(trip_m.group(1)) if trip_m else None
            if trip is None and cond and cond.group(1) in comps:
                ints = comps[cond.group(1)].const_ints
                trip = max(ints) if ints else None
            if trip is None:
                trip = 1
                cost.unknown_trip_whiles += 1
            cost.while_trips.append(trip)
            for ref in (body, cond):
                if ref and ref.group(1) in comps:
                    sub = _comp_cost(comps[ref.group(1)], comps, shapes, memo, tpu_dtype_correction)
                    cost.merge_scaled(sub, trip)
        elif op == "fusion":
            m = _ATTR_CALLS.search(ins.rest)
            if m and m.group(1) in comps:
                called = comps[m.group(1)]
                sub = _comp_cost(called, comps, shapes, memo)
                # fusions: internal flops count, internal traffic doesn't —
                # HBM traffic happens at the fusion boundary
                cost.dot_flops += sub.dot_flops
                cost.collective_traffic += sub.collective_traffic
                for opn, st in sub.collectives.items():
                    mine = cost.collectives.setdefault(opn, {"count": 0.0, "bytes": 0.0, "traffic": 0.0})
                    for f in ("count", "bytes", "traffic"):
                        mine[f] += st[f]
                ft = _fusion_traffic(ins, called, comps, shapes)
                cost.traffic_bytes += ft
                # pure dtype-conversion fusions (XLA CPU wraps the f32<->bf16
                # casts it inserts around bf16 dots): attribute as convert
                # churn so the TPU-corrected memory term can exclude them
                body_ops = {i.op for i in called.instrs if i.op != "parameter"}
                if body_ops and body_ops <= {"convert", "copy", "bitcast"}:
                    cost.convert_traffic += ft
        elif op == "call":
            m = _ATTR_TO_APPLY.search(ins.rest)
            if m and m.group(1) in comps:
                cost.merge_scaled(_comp_cost(comps[m.group(1)], comps, shapes, memo, tpu_dtype_correction), 1.0)
        elif op == "conditional":
            m = _ATTR_BRANCHES.search(ins.rest)
            if m:
                subs = [
                    _comp_cost(comps[n.strip().lstrip("%")], comps, shapes, memo, tpu_dtype_correction)
                    for n in m.group(1).split(",")
                    if n.strip().lstrip("%") in comps
                ]
                if subs:
                    worst = max(subs, key=lambda c: c.dot_flops + c.traffic_bytes)
                    cost.merge_scaled(worst, 1.0)
        elif op in _SKIP_CALLED:
            pass
    memo[comp.name] = cost
    return cost


def _fusion_traffic(ins: _Instr, called: _Comp, comps: Dict[str, _Comp],
                    shapes: Dict[str, str]) -> float:
    """Boundary traffic of a fusion: result + per-operand effective bytes.

    A fusion operand consumed *only* through dynamic-slice / as the target of
    dynamic-update-slice (the scan access pattern) is charged the slice
    bytes, not the whole (L, ...) stacked buffer — otherwise loop-carried
    stacks would be overcounted by n_layers.
    """
    # map internal parameter name -> (index, full bytes)
    params: Dict[str, Tuple[int, int]] = {}
    for i in called.instrs:
        if i.op == "parameter":
            mm = re.match(r"\s*(\d+)", i.args)
            if mm:
                params[i.name] = (int(mm.group(1)), _type_bytes(i.rtype))
    indexed_bytes: Dict[str, float] = {n: 0.0 for n in params}
    full: Dict[str, bool] = {n: False for n in params}
    for i in called.instrs:
        if i.op == "parameter":
            continue
        refs = _OPERAND_RE.findall(i.args)
        for pos, r in enumerate(refs):
            if r not in params:
                continue
            if i.op == "dynamic-slice" and pos == 0:
                indexed_bytes[r] = max(indexed_bytes[r], 2.0 * _type_bytes(i.rtype))
            elif i.op == "dynamic-update-slice" and pos == 0 and len(refs) > 1:
                ub = _type_bytes(shapes.get(refs[1], "f32[]"))
                indexed_bytes[r] = max(indexed_bytes[r], 2.0 * ub)
            else:
                full[r] = True
    by_index: Dict[int, float] = {}
    for name, (idx, fb) in params.items():
        by_index[idx] = float(fb) if full[name] or indexed_bytes[name] == 0.0 else indexed_bytes[name]
    total = float(_type_bytes(ins.rtype))
    operand_names = _OPERAND_RE.findall(ins.args)
    for pos, name in enumerate(operand_names):
        if pos in by_index:
            total += by_index[pos]
        elif name in shapes:
            total += _type_bytes(shapes[name])
    return total


def analyze_hlo(text: str, tpu_dtype_correction: bool = True) -> HloCost:
    comps, shapes, entry = _parse_module(text)
    if entry is None or entry not in comps:
        return HloCost()
    memo: Dict[str, HloCost] = {}
    return _comp_cost(comps[entry], comps, shapes, memo, tpu_dtype_correction)


# --- legacy helpers (kept for tests/benchmarks) --------------------------------

def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    return analyze_hlo(hlo_text).collectives


def total_collective_traffic(hlo_text: str) -> float:
    return analyze_hlo(hlo_text).collective_traffic


def op_histogram(hlo_text: str, top: int = 25) -> Dict[str, int]:
    ops = re.findall(
        r"=\s*(?:\([^)]*\)|[a-z0-9_]+\[[^\]]*\][^\s]*)\s+([a-z][a-z0-9-]*)\(", hlo_text
    )
    return dict(Counter(ops).most_common(top))
