"""GPipe-style microbatch pipeline over a mesh axis (default: "pod").

For cross-pod scaling where DCN bandwidth makes pod-spanning FSDP/TP
expensive, layers can instead be partitioned into S = |pod| stages and
microbatches streamed through with ``ppermute`` hops (one inter-pod transfer
of one activation tensor per microbatch per boundary — the cheapest possible
cross-pod pattern).  Off by default: the measured default for the assigned
meshes is DP over `pod` (see DESIGN §5); this module + its test exist as the
1000-node lever.

Bubble fraction: (S-1)/(M+S-1) for M microbatches.

`gpipe_apply` is deliberately schedule-transparent: a python loop over
T = M+S-1 ticks, each tick = one stage_fn application + one ppermute, so the
lowered HLO shows exactly T collective-permutes (inspectable by the same
hlo_analysis used for the roofline).
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def gpipe_apply(
    stage_fn: Callable,
    stage_params,          # pytree, every leaf stacked (S, ...) by stage
    microbatches: jax.Array,  # (M, mb, ...) replicated input
    mesh: Mesh,
    axis: str = "pod",
) -> jax.Array:
    """Run microbatches through S pipeline stages; returns (M, mb, ...)."""
    n_stages = mesh.shape[axis]
    n_micro = microbatches.shape[0]

    def inner(params, xs):
        # params: stage-local slice (1, ...); xs: all microbatches (replicated)
        idx = jax.lax.axis_index(axis)
        local = jax.tree.map(lambda p: p[0], params)
        buf = jnp.zeros_like(xs[0])
        ys = jnp.zeros_like(xs)
        perm = [(i, i + 1) for i in range(n_stages - 1)]
        for t in range(n_micro + n_stages - 1):
            feed = xs[t] if t < n_micro else jnp.zeros_like(xs[0])
            inp = jnp.where(idx == 0, feed, buf)
            out = stage_fn(local, inp)
            m = t - (n_stages - 1)
            if 0 <= m < n_micro:
                ys = jnp.where(idx == n_stages - 1, ys.at[m].set(out), ys)
            buf = jax.lax.ppermute(out, axis, perm)
        # deliver the last stage's collected outputs to every shard
        ys = jax.lax.psum(
            jnp.where(idx == n_stages - 1, ys, jnp.zeros_like(ys)), axis
        )
        return ys[None]  # (1, M, mb, ...) per shard

    stacked_spec = jax.tree.map(lambda _: P(axis), stage_params)
    out = shard_map(
        inner,
        mesh=mesh,
        in_specs=(stacked_spec, P()),
        out_specs=P(axis),
        check_rep=False,
    )(stage_params, microbatches)
    return out[0]


def sequential_reference(stage_fn: Callable, stage_params, microbatches: jax.Array) -> jax.Array:
    """Oracle: fold every stage over every microbatch sequentially."""
    n_stages = jax.tree.leaves(stage_params)[0].shape[0]
    outs = []
    for m in range(microbatches.shape[0]):
        x = microbatches[m]
        for s in range(n_stages):
            x = stage_fn(jax.tree.map(lambda p: p[s], stage_params), x)
        outs.append(x)
    return jnp.stack(outs)
