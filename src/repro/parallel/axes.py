"""Logical sharding-constraint context.

Model code never mentions mesh axes; it calls ``constrain(x, logical)`` with
logical names ("batch", "vocab", ...).  The step builder installs a
(mesh, rules) context during tracing; outside any context (smoke tests on
one device) ``constrain`` is a no-op.

This is how activation shardings are pinned at the places GSPMD propagation
loses them (post-embedding gather, post-unembed contraction, block
boundaries) — without it, the FSDP-sharded unembed contraction drops the
batch sharding of the logits and the loss path replicates (observed:
181 GB/device temp on qwen2 train_4k before this fix; see EXPERIMENTS §Perf).
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from .sharding import POLICIES, Rules, resolve_pspec

_CTX: contextvars.ContextVar = contextvars.ContextVar("repro_logical_ctx", default=None)


@contextlib.contextmanager
def logical_context(mesh: Mesh, policy: str = "train"):
    token = _CTX.set((mesh, POLICIES[policy]))
    try:
        yield
    finally:
        _CTX.reset(token)


def constrain(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    pspec = resolve_pspec(x.shape, tuple(logical), mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))
