"""In-memory tuple store (DBx1000 analogue).

Each tuple cell carries: value bytes, SSN (the per-tuple sequence number of
Algorithm 1), and a write lock with owner tracking (OCC validation needs
"locked by another transaction" visibility).  Locks are per-tuple and
non-blocking to acquire (``try_lock``), matching the validation-phase
primary-key-ordered locking of §4.4.
"""

from __future__ import annotations

import bisect
import threading
from typing import Dict, Iterable, Iterator, List, Optional, Tuple


class TupleCell:
    __slots__ = ("key", "value", "ssn", "_owner", "_lock")

    def __init__(self, key: str, value: bytes = b""):
        self.key = key
        self.value = value
        self.ssn = 0
        self._owner = 0          # tid holding the write lock (0 = free)
        self._lock = threading.Lock()

    def try_lock(self, tid: int) -> bool:
        if self._lock.acquire(blocking=False):
            self._owner = tid
            return True
        return False

    def lock(self, tid: int) -> None:
        self._lock.acquire()
        self._owner = tid

    def unlock(self, tid: int) -> None:
        assert self._owner == tid, f"unlock by non-owner {tid} != {self._owner}"
        self._owner = 0
        self._lock.release()

    def locked_by_other(self, tid: int) -> bool:
        return self._owner not in (0, tid)


class Table:
    """A flat key space of tuple cells (composite keys encode TPC-C tables).

    Sorted-key cache behaviour: :meth:`sorted_keys` materializes the sorted
    key list lazily and caches it; any :meth:`insert` of a *new* key
    invalidates the cache (value updates of existing keys do not), so range
    scans and checkpoint partitioning pay the sort only after the key space
    actually changes.  Under insert-heavy workloads interleaved with scans
    this re-sorts per new key — an index (e.g. a B-tree) would amortize
    that; for the fixed-format benchmark key spaces here the key set is
    static after load.
    """

    def __init__(self, name: str = "main"):
        self.name = name
        self._cells: Dict[str, TupleCell] = {}
        self._insert_lock = threading.Lock()
        self._sorted_cache: Optional[List[str]] = None

    def insert(self, key: str, value: bytes) -> TupleCell:
        with self._insert_lock:
            cell = self._cells.get(key)
            if cell is None:
                cell = TupleCell(key, value)
                self._cells[key] = cell
                self._sorted_cache = None
            else:
                cell.value = value
            return cell

    def get(self, key: str) -> Optional[TupleCell]:
        return self._cells.get(key)

    def get_or_insert(self, key: str) -> TupleCell:
        cell = self._cells.get(key)
        if cell is None:
            return self.insert(key, b"")
        return cell

    def __len__(self) -> int:
        return len(self._cells)

    def __contains__(self, key: str) -> bool:
        return key in self._cells

    # --- checkpoint support (§5) -------------------------------------------
    def sorted_keys(self) -> List[str]:
        cache = self._sorted_cache
        if cache is None:
            cache = sorted(self._cells)
            self._sorted_cache = cache
        return cache

    def partitions(self, n: int) -> List[List[str]]:
        """Evenly divide the key space into n partitions (key order)."""
        keys = self.sorted_keys()
        size = (len(keys) + n - 1) // n
        return [keys[i * size : (i + 1) * size] for i in range(n)]

    def snapshot_partition(self, keys: Iterable[str]) -> Iterator[Tuple[bytes, bytes, int]]:
        """Fuzzy-scan a partition: yields (key, value, ssn) without any
        coordination with writers (per-tuple reads are atomic under GIL)."""
        for k in keys:
            cell = self._cells.get(k)
            if cell is not None:
                yield k.encode(), cell.value, cell.ssn

    def scan_range(self, start_key: str, length: int) -> List[TupleCell]:
        """Key-range scan of ``length`` tuples (hybrid YCSB workload).
        Uses lexicographic order over the materialized key list."""
        # note: for benchmark purposes keys are fixed-format so lexicographic
        # order == logical order; a real system would use an index.
        keys = self.sorted_keys()
        i = bisect.bisect_left(keys, start_key)
        return [self._cells[k] for k in keys[i : i + length]]
