"""TPC-C subset (paper §6.2): 50% Payment + 50% NewOrder over W warehouses.

Composite keys encode the nine-table schema in the flat store:
  W:<w>                warehouse (ytd)
  D:<w>:<d>            district (ytd, next_o_id)
  C:<w>:<d>:<c>        customer (balance, ytd_payment)
  I:<i>                item (price)
  S:<w>:<i>            stock (quantity)
  O:<w>:<d>:<o>        order header
  OL:<w>:<d>:<o>:<n>   order line

Payment: update warehouse/district YTD + customer balance (read-modify-write
=> RAW-carrying txns).  NewOrder: read item prices, decrement stock, insert
order + order lines (mostly write-heavy with stock RMW).

Scaled: 20 warehouses (paper) with reduced customers/items per warehouse —
ratios between logging variants are the reproduction target.
"""

from __future__ import annotations

import random
import struct
from typing import Callable, List, Optional, Tuple

from .batch import TxnSpec
from .occ import OCCWorker
from .table import Table

# value-lookup hook for spec generation: key -> (value bytes, observed ssn).
# The dict-table adapter wraps TupleCell; ArrayTable.get_or_insert already
# has this exact signature, so batch generation runs against either store.
Lookup = Callable[[str], Tuple[bytes, int]]

DISTRICTS = 10
CUSTOMERS = 120        # per district (paper: 3000; scaled)
ITEMS = 2000           # (paper: 100k; scaled)


def _f(x: float) -> bytes:
    return struct.pack("<d", x)


def _fi(b: bytes) -> float:
    return struct.unpack("<d", b[:8])[0] if len(b) >= 8 else 0.0


def load(table: Table, warehouses: int = 20, seed: int = 11) -> None:
    rng = random.Random(seed)
    for i in range(ITEMS):
        table.insert(f"I:{i}", _f(rng.uniform(1, 100)))
    for w in range(warehouses):
        table.insert(f"W:{w}", _f(0.0))
        for d in range(DISTRICTS):
            table.insert(f"D:{w}:{d}", struct.pack("<dI", 0.0, 1))
            for c in range(CUSTOMERS):
                table.insert(f"C:{w}:{d}:{c}", _f(0.0))
        for i in range(ITEMS):
            table.insert(f"S:{w}:{i}", struct.pack("<I", rng.randrange(10, 100)))


class TPCC:
    def __init__(self, table: Table, warehouses: int = 20, seed: int = 0):
        self.table = table
        self.warehouses = warehouses
        self.rng = random.Random(seed)
        self._order_seq = 0

    def _dict_lookup(self, key: str) -> Tuple[bytes, int]:
        cell = self.table.get_or_insert(key)
        return cell.value, cell.ssn

    def next_txn(self, worker: OCCWorker):
        spec = self.next_spec(self._dict_lookup)
        return worker.execute(reads=spec.reads, writes=spec.writes)

    def next_spec(self, lookup: Optional[Lookup] = None) -> TxnSpec:
        """Generate one Payment/NewOrder intent; ``lookup`` supplies the
        values the read-modify-writes are computed from (and the observed
        SSNs the batched validator will re-check)."""
        lookup = lookup or self._dict_lookup
        if self.rng.random() < 0.5:
            return self._payment_spec(lookup)
        return self._new_order_spec(lookup)

    def next_batch(self, n: int, lookup: Optional[Lookup] = None) -> List[TxnSpec]:
        """``n`` specs for the batched executor.  Pass the columnar store's
        ``ArrayTable.get_or_insert`` as ``lookup`` to generate against it;
        losers must be *regenerated* (their values derive from the observed
        reads), which the batch drivers do by drawing fresh transactions."""
        return [self.next_spec(lookup) for _ in range(n)]

    def _payment_spec(self, lookup: Lookup) -> TxnSpec:
        rng = self.rng
        w = rng.randrange(self.warehouses)
        d = rng.randrange(DISTRICTS)
        c = rng.randrange(CUSTOMERS)
        amount = rng.uniform(1, 5000)
        wk, dk, ck = f"W:{w}", f"D:{w}:{d}", f"C:{w}:{d}:{c}"
        # read-modify-write of three rows
        (wv, wssn), (dv, dssn), (cv, cssn) = lookup(wk), lookup(dk), lookup(ck)
        writes = [
            (wk, _f(_fi(wv) + amount)),
            (dk, struct.pack("<dI", _fi(dv) + amount, 1)),
            (ck, _f(_fi(cv) - amount)),
        ]
        return TxnSpec(reads=[wk, dk, ck], writes=writes,
                       observed=[wssn, dssn, cssn])

    def _new_order_spec(self, lookup: Lookup) -> TxnSpec:
        rng = self.rng
        w = rng.randrange(self.warehouses)
        d = rng.randrange(DISTRICTS)
        n_lines = rng.randrange(5, 16)
        items = rng.sample(range(ITEMS), n_lines)
        self._order_seq += 1
        o = self._order_seq
        reads = [f"I:{i}" for i in items] + [f"D:{w}:{d}"]
        observed = [lookup(k)[1] for k in reads]
        writes: List[Tuple[str, bytes]] = [(f"O:{w}:{d}:{o}", struct.pack("<II", n_lines, w))]
        for n, i in enumerate(items):
            sk = f"S:{w}:{i}"
            reads.append(sk)
            sv, sssn = lookup(sk)
            observed.append(sssn)
            qty = struct.unpack("<I", sv[:4])[0] if len(sv) >= 4 else 50
            qty = qty - 1 if qty > 10 else qty + 91
            writes.append((sk, struct.pack("<I", qty)))
            writes.append((f"OL:{w}:{d}:{o}:{n}", struct.pack("<Id", i, rng.uniform(1, 100))))
        return TxnSpec(reads=reads, writes=writes, observed=observed)
