"""Batched array-native OCC + SSN allocation (paper §4.2/§4.4, batched).

The scalar forward path (`repro.db.occ.OCCWorker`) runs one transaction at a
time: per-tuple ``threading.Lock`` round-trips for validation, one buffer
latch acquisition per SSN allocation, one ``Txn.encode()`` per record.
Poplar's own argument — only RAW/WAW dependencies constrain ordering — means
a whole *batch* of transactions can be validated, sequenced, encoded, and
published with array ops instead.  This module is that pipeline:

1. **flatten** — the batch's read/write keys are mapped onto
   :class:`~repro.db.array_table.ArrayTable` rows once (``rows_for``),
   producing transaction-major access arrays reused across retry rounds;
2. **validate** (per round) — intra-batch WW and RW conflicts reduce to one
   segmented *min* over write positions per tuple row: a transaction
   survives iff every tuple it touches has ``first_writer_pos >= its own
   batch position`` (first-come-wins; losers are retried next round or
   returned as aborted).  Driver-observed SSNs (read-modify-write
   workloads) are validated with one vectorized compare against the
   current ``table.ssn`` column; foreign write locks with one gather of
   ``table.lock_owner``;
3. **sequence** — per-transaction base SSNs are one segmented *max* over
   tuple SSNs (Algorithm 1 lines 1–4, ``ssn.base_ssn_batch``), then each
   buffer's winners take SSNs + slots through a single
   :meth:`~repro.core.log_buffer.LogBuffer.reserve_batch` latch
   acquisition (closed-form ``max``-chain + prefix-summed offsets);
4. **publish** — winning records are encoded into one contiguous blob
   (``core.txn.encode_batch``, byte-identical to per-record
   ``Txn.encode``) and land in the ring via one
   :meth:`~repro.core.engine.PoplarEngine.publish_batch` memcpy; tuple
   values/SSNs write back as two scatters.

With ``mode="pallas"`` steps 2 and 3 fuse into ONE compiled device pass
(:func:`repro.kernels.ops.fused_validate_sequence`): the round's access
columns leave the host as a single bucket-padded int32 transfer in a dense
``(n_txn, k)`` layout and ``(survive, bases)`` come back together —
first-writer min, the three validation masks, the survive reduction and the
base-SSN max all on-device, compiled on every backend.  Batches out of
profile (too small to beat the dispatch floor, pathological access skew,
values beyond int32) fall back per round to the numpy reductions — or, for
the individual segmented reduces, the Pallas one-hot kernel
(``kernels/batch_occ.py``) — with identical results.

:class:`ScalarBatchOCC` is the correctness oracle (same pattern as
recovery's ``mode="scalar"``): identical batch semantics, executed with the
existing scalar machinery — dict :class:`~repro.db.table.Table` cells,
per-transaction ``engine.allocate``/``publish``.  The equivalence contract
(same winners, same tids, same per-tuple SSNs, byte-identical logs) is
property-tested in ``tests/test_batch_occ.py``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import ssn as ssn_mod
from ..core.engine import LoggingEngine
from ..core.txn import FLAG_HAS_READS, Txn, encode_batch, encode_batch_columns
from ..trace.span import (
    ST_ENCODE,
    ST_SEQUENCE,
    ST_VALIDATE,
    ST_WRITEBACK,
    TRACER,
)
from ..kernels.bucketing import bucket, fits_i32, pad_i32, stack_i32
from ..obs.metrics import REGISTRY
from .array_table import ArrayTable
from .occ import TID_STRIDE, TidStripe
from .table import Table

NO_WRITER = np.int64(np.iinfo(np.int64).max)

# framed-record overhead: header (u32 len + u32 crc) + fixed payload
# (u64 ssn + u64 tid + u8 flags + u32 n_writes); per-write u32 klen + u32 vlen
_REC_FIXED = 8 + 21
_PER_WRITE = 8


@dataclass(slots=True)
class TxnSpec:
    """One transaction intent for the batched executor.

    ``observed`` (optional, aligned with ``reads``) carries the tuple SSNs
    the driver saw when it computed the write values (read-modify-write
    workloads like TPC-C); if given, the validator aborts the transaction
    when any of them is stale.  Without it, reads are observed fresh at each
    round start.

    ``cmd_op``/``cmd_params`` (optional, params aligned with ``writes``)
    declare the *command form* of the transaction: a registered op id
    (:mod:`repro.core.command`) and the per-write parameter such that
    ``op(pre_image, param) == write value``.  They are advisory — the
    executor's :class:`~repro.core.engine.AdaptivePolicy` decides per record
    whether to log the command form or the value form; without a policy (or
    when ineligible: unregistered op, blind writes, cross-shard) the spec
    logs values exactly as before.  The params-match-values contract is the
    workload's to keep; the crash-equivalence suite pins it.
    """

    reads: Sequence[str] = ()
    writes: Sequence[Tuple[str, bytes]] = ()
    observed: Optional[Sequence[int]] = None
    cmd_op: Optional[int] = None
    cmd_params: Optional[Sequence[bytes]] = None


@dataclass
class BatchResult:
    committed: List[Txn] = field(default_factory=list)
    committed_idx: List[int] = field(default_factory=list)  # spec index per Txn
    aborted: List[int] = field(default_factory=list)        # never-won spec indices
    rounds: int = 0


def _pow2(n: int) -> int:
    """Next power of two ≥ n (≥ 1): the pallas mode pads its kernel inputs
    to power-of-two buckets so jit traces are reused across batches/rounds
    instead of recompiling for every distinct shape."""
    return 1 << max(n - 1, 0).bit_length()


def _pad_i32(a: np.ndarray, n: int, fill: int) -> np.ndarray:
    out = np.full(n, fill, dtype=np.int32)
    out[: len(a)] = a
    return out


def _concat_ranges(starts: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """Indices of the concatenation of ``[starts[i], starts[i]+lens[i])``."""
    total = int(lens.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64)
    out_starts = np.zeros(len(lens), dtype=np.int64)
    np.cumsum(lens[:-1], out=out_starts[1:])
    return np.arange(total, dtype=np.int64) + np.repeat(starts - out_starts, lens)


class _Flat:
    """The batch flattened into transaction-major access arrays (built once,
    reused across retry rounds — keys never change, only table state).

    Built either from string-keyed :class:`TxnSpec`s (:meth:`from_specs`,
    one Python pass mapping keys to rows) or directly from read-index /
    write-index arrays (:meth:`from_indexed`, fully vectorized — the form
    the ISSUE's batched validator takes)."""

    specs: Optional[Sequence[TxnSpec]]

    @classmethod
    def from_specs(
        cls, table: ArrayTable, specs: Sequence[TxnSpec], policy=None
    ) -> "_Flat":
        self = cls.__new__(cls)
        self.specs = specs
        b = len(specs)
        all_keys: List[str] = []
        wr_vals: List[bytes] = []
        obs_l: List[int] = []
        self.rd_len = np.empty(b, dtype=np.int64)
        self.wr_len = np.empty(b, dtype=np.int64)
        self.rec_len = np.empty(b, dtype=np.int64)
        # adaptive framing (decided here because the reservation lengths
        # depend on it — the drift guard in _run pins encode to these):
        # per-spec command flag, op id, (key, dep ssn) list, logged write set
        self.is_cmd = np.zeros(b, dtype=bool)
        self.cmd_op_arr = np.zeros(b, dtype=np.int64)
        self.cmd_deps: List[Optional[List[Tuple[str, int]]]] = [None] * b
        self.cmd_writes: List[Optional[List[Tuple[str, bytes]]]] = [None] * b
        for i, s in enumerate(specs):
            nr, nw = len(s.reads), len(s.writes)
            assert nr + nw > 0, f"spec {i} has no reads and no writes"
            if s.observed is not None:
                assert len(s.observed) == nr, f"spec {i}: observed/reads mismatch"
                obs_l.extend(int(o) for o in s.observed)
            else:
                obs_l.extend((-1,) * nr)
            self.rd_len[i] = nr
            self.wr_len[i] = nw
            all_keys.extend(s.reads)
            as_cmd = False
            if policy is not None and s.cmd_op is not None:
                # dep = observed pre-image SSN per written key; eligible only
                # when every write has one (the spec read what it overwrites)
                obs_map = (
                    dict(zip(s.reads, s.observed))
                    if s.observed is not None else {}
                )
                deps = [int(obs_map.get(k, -1)) for k, _ in s.writes]
                params = s.cmd_params
                as_cmd = (
                    params is not None
                    and len(params) == nw
                    and policy.eligible(s.cmd_op, deps)
                )
            rec = _REC_FIXED
            if as_cmd:
                self.is_cmd[i] = True
                self.cmd_op_arr[i] = s.cmd_op
                self.cmd_deps[i] = [
                    (k, int(d)) for (k, _), d in zip(s.writes, deps)
                ]
                self.cmd_writes[i] = [
                    (k, p) for (k, _), p in zip(s.writes, params)
                ]
                rec += 8  # command footer prefix (u32 op + u32 n_deps)
                for (k, v), p in zip(s.writes, params):
                    all_keys.append(k)
                    wr_vals.append(v)
                    klen = len(k) if k.isascii() else len(k.encode())
                    # write chain carries the param; dep entry repeats the key
                    rec += _PER_WRITE + len(p) + klen + 12 + klen
            else:
                for k, v in s.writes:
                    all_keys.append(k)
                    wr_vals.append(v)
                    # keys are str; ascii length == encoded length (fast path)
                    rec += _PER_WRITE + len(v) + (
                        len(k) if k.isascii() else len(k.encode())
                    )
            self.rec_len[i] = rec

        self.acc_len = self.rd_len + self.wr_len
        self.acc_start = np.zeros(b + 1, dtype=np.int64)
        np.cumsum(self.acc_len, out=self.acc_start[1:])
        self.acc_row = table.rows_for(all_keys)
        self.acc_txn = np.repeat(np.arange(b, dtype=np.int64), self.acc_len)
        # reads occupy the first rd_len slots of each txn's access segment
        self.acc_obs = np.full(int(self.acc_start[-1]), -1, dtype=np.int64)
        rd_idx = _concat_ranges(self.acc_start[:-1], self.rd_len)
        if obs_l:
            self.acc_obs[rd_idx] = np.asarray(obs_l, dtype=np.int64)
        self.acc_iswrite = np.ones(int(self.acc_start[-1]), dtype=bool)
        self.acc_iswrite[rd_idx] = False
        # per-txn write slices into the flat per-write value list
        self.wr_start = np.zeros(b + 1, dtype=np.int64)
        np.cumsum(self.wr_len, out=self.wr_start[1:])
        self.wr_row = self.acc_row[self.acc_iswrite]
        self.wr_vals = np.empty(len(wr_vals), dtype=object)
        self.wr_vals[:] = wr_vals
        self.wr_vlen = None
        return self

    @classmethod
    def from_indexed(
        cls,
        table: ArrayTable,
        rd_row: np.ndarray,
        rd_start: np.ndarray,
        wr_row: np.ndarray,
        wr_start: np.ndarray,
        wr_vals: Sequence[bytes],
        observed: Optional[np.ndarray] = None,
        wr_vlen: Optional[np.ndarray] = None,
    ) -> "_Flat":
        """Vectorized flatten from row-index arrays: ``rd_start``/``wr_start``
        are ``(B+1,)`` prefixes delimiting each transaction's slice of
        ``rd_row``/``wr_row``; ``observed`` (optional) aligns with
        ``rd_row``; ``wr_vlen`` (optional) skips the value-length pass."""
        self = cls.__new__(cls)
        self.specs = None
        b = len(rd_start) - 1
        # indexed batches are value-only (no specs to carry an op form)
        self.is_cmd = np.zeros(b, dtype=bool)
        self.cmd_op_arr = np.zeros(b, dtype=np.int64)
        self.cmd_deps = [None] * b
        self.cmd_writes = [None] * b
        rd_row = np.asarray(rd_row, dtype=np.int64)
        wr_row = np.asarray(wr_row, dtype=np.int64)
        self.rd_len = np.diff(np.asarray(rd_start, dtype=np.int64))
        self.wr_len = np.diff(np.asarray(wr_start, dtype=np.int64))
        assert (self.rd_len + self.wr_len > 0).all(), "empty transaction in batch"
        self.acc_len = self.rd_len + self.wr_len
        self.acc_start = np.zeros(b + 1, dtype=np.int64)
        np.cumsum(self.acc_len, out=self.acc_start[1:])
        total = int(self.acc_start[-1])
        rd_pos = _concat_ranges(self.acc_start[:-1], self.rd_len)
        wr_pos = _concat_ranges(self.acc_start[:-1] + self.rd_len, self.wr_len)
        self.acc_row = np.empty(total, dtype=np.int64)
        self.acc_row[rd_pos] = rd_row
        self.acc_row[wr_pos] = wr_row
        self.acc_txn = np.repeat(np.arange(b, dtype=np.int64), self.acc_len)
        self.acc_obs = np.full(total, -1, dtype=np.int64)
        if observed is not None:
            self.acc_obs[rd_pos] = np.asarray(observed, dtype=np.int64)
        self.acc_iswrite = np.ones(total, dtype=bool)
        self.acc_iswrite[rd_pos] = False
        self.wr_start = np.asarray(wr_start, dtype=np.int64)
        self.wr_row = wr_row
        if isinstance(wr_vals, np.ndarray) and wr_vals.dtype == object:
            self.wr_vals = wr_vals
        else:
            self.wr_vals = np.empty(len(wr_vals), dtype=object)
            self.wr_vals[:] = wr_vals
        if wr_vlen is None:
            wr_vlen = np.fromiter(map(len, wr_vals), np.int64, len(wr_vals))
        self.wr_vlen = np.asarray(wr_vlen, dtype=np.int64)
        # framed record length from the table's key-length column
        wlen = _PER_WRITE + table.key_len[wr_row] + self.wr_vlen
        wcs = np.zeros(len(wr_row) + 1, dtype=np.int64)
        np.cumsum(wlen, out=wcs[1:])
        self.rec_len = _REC_FIXED + wcs[self.wr_start[1:]] - wcs[self.wr_start[:-1]]
        return self


class BatchOCC:
    """Array-native batched OCC executor over an :class:`ArrayTable`.

    ``mode="vectorized"`` (default) runs the segmented reductions in numpy;
    ``mode="pallas"`` routes them through the one-hot reduce kernel.  The
    engine must be a :class:`~repro.core.engine.PoplarEngine` (or expose the
    same ``buffer_for``/``buffers``/``publish_batch`` surface).
    """

    def __init__(
        self,
        table: ArrayTable,
        engine: LoggingEngine,
        n_workers: int = 1,
        mode: str = "vectorized",
        tid_stride: int = TID_STRIDE,
        worker_id_base: int = 0,
        policy=None,
    ):
        if mode not in ("vectorized", "pallas"):
            raise ValueError(f"unknown batch OCC mode {mode!r}")
        self.table = table
        self.engine = engine
        self.n_workers = n_workers
        self.mode = mode
        # adaptive command/value framing policy (core.engine.AdaptivePolicy);
        # None keeps the executor pure-value, byte-compatible with old logs
        self.policy = policy
        # worker_id_base offsets this executor's worker ids and tid stripes
        # into a disjoint slice of the global spaces — the injection point
        # that lets several executors (one per shard, `repro.shard`) share
        # one tid universe without a cross-shard allocator
        self.worker_id_base = worker_id_base
        self.stripes = [
            TidStripe(worker_id_base + w, tid_stride) for w in range(n_workers)
        ]
        for w in range(n_workers):
            engine.register_worker(worker_id_base + w)
        self.committed_submitted = 0
        self.aborts = 0  # per-round validation losses (retries count, like OCCWorker)
        # shard id stamped on trace spans (worker_id_base = shard * n_workers
        # by construction in repro.shard.engine; 0 for a single engine)
        self.trace_shard = worker_id_base // max(1, n_workers)
        # below this many access lanes the fused device round costs more than
        # the numpy reductions (dispatch + transfer floor); tests drop it to 0
        # to force the compiled path on tiny batches
        self.fused_min_lanes = 2048

    # --- fused validate→sequence (mode="pallas", compiled) --------------------
    def _fused_round(
        self,
        a_row: np.ndarray,
        a_pos: np.ndarray,
        iw: np.ndarray,
        obs: np.ndarray,
        ssn_now: np.ndarray,
        locked: np.ndarray,
        starts: np.ndarray,
        a_len: np.ndarray,
    ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """One round's validate→sequence on the device, fused: the gathered
        access columns leave the host as ONE stacked int32 transfer in a
        dense bucket-padded ``(n_txn, k)`` layout (every transaction's
        accesses replicated up to ``k`` lanes and masked by true length), and
        ``(survive, bases)`` come back together — replacing the first-writer
        scatter, three compare-masks, the survive ``reduceat`` and the
        base-SSN segmented max (see ``kernels.batch_occ.
        validate_sequence_xla`` for the masking rules).

        Returns ``None`` — the caller runs the numpy round instead, same
        results — when the batch is out of profile: too small to beat the
        dispatch+transfer floor, dense padding blowup under pathological
        access-count skew, or values outside int32 range.
        """
        total = len(a_row)
        n_active = len(a_len)
        if total < self.fused_min_lanes:
            if REGISTRY.enabled:
                REGISTRY.count("occ.fused.decline.small_batch")
            return None
        k = bucket(int(a_len.max()), min_size=1)
        n_txn = bucket(n_active)
        if n_txn * k > max(4 * total, 4096):
            if REGISTRY.enabled:
                REGISTRY.count("occ.fused.decline.dense_padding")
            return None                # dense layout would mostly be padding
        if not fits_i32(ssn_now, obs, a_row):
            if REGISTRY.enabled:
                REGISTRY.count("occ.fused.decline.i32_range")
            return None
        from ..kernels.ops import fused_validate_sequence

        # dense gather: txn j's lane l reads access start[j] + min(l, len-1)
        # — lanes past a txn's true count replicate its last access and are
        # masked out by a_len on the device
        len_p = np.ones(n_txn, np.int64)
        len_p[:n_active] = a_len
        st_p = np.zeros(n_txn, np.int64)
        st_p[:n_active] = starts[:-1]
        lane = np.arange(k, dtype=np.int64)[None, :]
        src = (st_p[:, None] + np.minimum(lane, len_p[:, None] - 1)).ravel()
        acc = stack_i32(
            [a_row[src], a_pos[src], iw[src], obs[src], ssn_now[src],
             locked[src]],
            n_txn * k, fills=(0,) * 6,
        )
        survive, bases = fused_validate_sequence(
            acc, pad_i32(a_len, n_txn, 0),
            n_txn=n_txn, k=k, cap=bucket(len(self.table.ssn)),
        )
        if REGISTRY.enabled:
            from ..kernels.bucketing import gauge_jit_cache

            gauge_jit_cache([fused_validate_sequence])
        return (
            np.asarray(survive)[:n_active],
            np.asarray(bases)[:n_active].astype(np.int64),
        )

    # --- segmented reductions -------------------------------------------------
    def _first_writer(
        self, w_row: np.ndarray, w_pos: np.ndarray, a_row: np.ndarray
    ) -> np.ndarray:
        """Per access, the smallest batch position among the batch's writers
        of that row (``NO_WRITER`` if the row is not written this round).
        ``w_pos`` is non-decreasing (txn-major flatten), so the stable sort's
        first element per row group is the segment min."""
        if not len(w_row):
            return np.full(len(a_row), NO_WRITER, dtype=np.int64)
        use_kernel = self.mode == "pallas" and int(w_pos.max()) < 2**31
        if use_kernel:
            uniq, inv = np.unique(w_row, return_inverse=True)
            from ..kernels.ops import occ_seg_reduce
            from ..kernels.batch_occ import NO_WRITER as _NW

            np_items = _pow2(len(inv))
            fw_uniq = np.asarray(
                occ_seg_reduce(
                    _pad_i32(inv, np_items, -1),
                    _pad_i32(w_pos, np_items, int(_NW)),
                    n_slots=_pow2(len(uniq)), op="min",
                )
            )[: len(uniq)].astype(np.int64)
        else:
            o = np.argsort(w_row, kind="stable")
            rs = w_row[o]
            first = np.empty(len(rs), dtype=bool)
            first[0] = True
            np.not_equal(rs[1:], rs[:-1], out=first[1:])
            uniq = rs[first]
            fw_uniq = w_pos[o][first]
        idx = np.searchsorted(uniq, a_row)
        idx_c = np.minimum(idx, len(uniq) - 1)
        hit = uniq[idx_c] == a_row
        return np.where(hit, fw_uniq[idx_c], NO_WRITER)

    def _base_ssns(
        self, ssn_now: np.ndarray, starts: np.ndarray, n_active: int
    ) -> np.ndarray:
        """Per-active-txn base SSN (Algorithm 1 lines 1–4, segmented max)."""
        if (
            self.mode == "pallas"
            and len(ssn_now)
            and int(ssn_now.max()) < 2**31
        ):
            from ..kernels.ops import occ_seg_reduce

            keys = np.repeat(
                np.arange(n_active, dtype=np.int64), np.diff(starts)
            )
            np_items = _pow2(len(keys))
            base = np.asarray(
                occ_seg_reduce(
                    _pad_i32(keys, np_items, -1),
                    _pad_i32(ssn_now, np_items, -1),
                    n_slots=_pow2(n_active), op="max",
                )
            )[:n_active].astype(np.int64)
            return np.maximum(base, 0)  # empty segments come back as -1
        return ssn_mod.base_ssn_batch(ssn_now, starts)

    # --- the pipeline --------------------------------------------------------
    def execute_batch(
        self,
        specs: Sequence[TxnSpec],
        worker_ids: Optional[Sequence[int]] = None,
        max_rounds: int = 1,
    ) -> BatchResult:
        """Run one batch through validate → sequence → publish, retrying
        round losers up to ``max_rounds`` times (first-come-wins within each
        round).  Returns the committed ``Txn``s (pre-committed, durably
        committed once the engine drains them) and the never-won indices."""
        if len(specs) == 0:
            return BatchResult()
        t_ent = time.perf_counter() if TRACER.enabled else None
        return self._run(_Flat.from_specs(self.table, specs, self.policy),
                         worker_ids, max_rounds, t_enter=t_ent)

    def execute_indexed(
        self,
        rd_row: np.ndarray,
        rd_start: np.ndarray,
        wr_row: np.ndarray,
        wr_start: np.ndarray,
        wr_vals: Sequence[bytes],
        worker_ids: Optional[Sequence[int]] = None,
        observed: Optional[np.ndarray] = None,
        wr_vlen: Optional[np.ndarray] = None,
        max_rounds: int = 1,
    ) -> BatchResult:
        """Fully array-native entry: the batch arrives as read-index /
        write-index arrays over the table's rows (``rd_start``/``wr_start``
        are ``(B+1,)`` per-txn prefixes), with per-write value payloads.
        No string keys are touched until record framing, which pulls the
        encoded key bytes from the table's own columns
        (``encode_batch_columns``).  The committed ``Txn`` objects carry
        only tid/ssn/worker bookkeeping (their read/write sets are not
        materialized); everything else matches :meth:`execute_batch`."""
        if len(rd_start) <= 1:
            return BatchResult()
        t_ent = time.perf_counter() if TRACER.enabled else None
        flat = _Flat.from_indexed(self.table, rd_row, rd_start, wr_row,
                                  wr_start, wr_vals, observed, wr_vlen)
        return self._run(flat, worker_ids, max_rounds, t_enter=t_ent)

    def _run(
        self,
        flat: _Flat,
        worker_ids: Optional[Sequence[int]],
        max_rounds: int,
        t_enter: Optional[float] = None,
    ) -> BatchResult:
        b = len(flat.rd_len)
        res = BatchResult()
        if worker_ids is None:
            worker_ids = [
                self.worker_id_base + i % self.n_workers for i in range(b)
            ]
        workers = np.asarray(worker_ids, dtype=np.int64)
        specs = flat.specs
        table = self.table
        t_start = time.perf_counter()

        active = np.arange(b, dtype=np.int64)
        _trace = TRACER.enabled
        while len(active) and res.rounds < max_rounds:
            res.rounds += 1
            if _trace:
                _bid = TRACER.next_batch_id()
                TRACER.ctx.batch = _bid
                TRACER.ctx.shard = self.trace_shard
                # first round: the span starts at entry so the spec
                # flattening cost is attributed to validate, not lost
                _tv0 = t_enter if t_enter is not None else time.perf_counter()
                t_enter = None
            with table.mutex:
                # --- gather the round's access view -------------------------
                a_len = flat.acc_len[active]
                a_idx = _concat_ranges(flat.acc_start[active], a_len)
                a_row = flat.acc_row[a_idx]
                a_pos = flat.acc_txn[a_idx]      # global batch positions
                starts = np.zeros(len(active) + 1, dtype=np.int64)
                np.cumsum(a_len, out=starts[1:])
                ssn_now = table.ssn[a_row]

                # --- validate + sequence -----------------------------------
                iw = flat.acc_iswrite[a_idx]
                obs = flat.acc_obs[a_idx]
                locked = table.locked_rows(a_row)
                fused = (
                    self._fused_round(a_row, a_pos, iw, obs, ssn_now, locked,
                                      starts, a_len)
                    if self.mode == "pallas" else None
                )
                if fused is not None:
                    survive, bases_all = fused
                    if REGISTRY.enabled:
                        REGISTRY.count("occ.fused.rounds")
                else:
                    fw = self._first_writer(a_row[iw], a_pos[iw], a_row)
                    ok = fw >= a_pos
                    np.logical_and(ok, (obs < 0) | (ssn_now == obs), out=ok)
                    np.logical_and(ok, ~locked, out=ok)
                    survive = np.logical_and.reduceat(ok, starts[:-1])
                    bases_all = None
                win_local = np.flatnonzero(survive)
                self.aborts += len(active) - len(win_local)
                if REGISTRY.enabled:
                    REGISTRY.count("occ.validate.wins", len(win_local))
                    REGISTRY.count("occ.validate.losses",
                                   len(active) - len(win_local))
                if _trace:
                    _tv1 = time.perf_counter()
                    TRACER.record(
                        ST_VALIDATE, shard=self.trace_shard, batch=_bid,
                        t0=_tv0, t1=_tv1, n_txn=len(active),
                        aux=len(win_local),
                    )
                if not len(win_local):
                    break  # nothing can make progress without external change
                win = active[win_local]

                # --- publish the winners -----------------------------------
                bases = (
                    bases_all[win_local] if bases_all is not None
                    else self._base_ssns(ssn_now, starts, len(active))[win_local]
                )
                txns: List[Txn] = []
                if specs is not None:
                    for j, i in zip(win_local.tolist(), win.tolist()):
                        spec = specs[i]
                        w = int(workers[i])
                        t = Txn(tid=self.stripes[w - self.worker_id_base].next())
                        t.worker_id = w  # type: ignore[attr-defined]
                        t.t_start = t_start
                        if spec.reads:
                            robs = ssn_now[starts[j] : starts[j] + len(spec.reads)]
                            t.read_set = list(zip(spec.reads, robs.tolist()))
                        if flat.is_cmd[i]:
                            # command framing: the logged write chain carries
                            # the op params; the dep ssns were validated this
                            # round so they ARE the live pre-image versions
                            t.cmd_op = int(flat.cmd_op_arr[i])
                            t.cmd_deps = flat.cmd_deps[i]
                            t.write_set = flat.cmd_writes[i]
                        else:
                            t.write_set = list(spec.writes)
                            if REGISTRY.enabled and spec.cmd_op is not None:
                                REGISTRY.count("adaptive.policy.forced_value")
                        txns.append(t)
                else:
                    # indexed mode: bookkeeping-only Txns (read_set is a
                    # sentinel so Qww/Qwr routing and the HAS_READS flag
                    # stay correct; sets are not materialized)
                    for i, nr in zip(win.tolist(), flat.rd_len[win].tolist()):
                        w = int(workers[i])
                        t = Txn(tid=self.stripes[w - self.worker_id_base].next())
                        t.worker_id = w  # type: ignore[attr-defined]
                        t.t_start = t_start
                        if nr:
                            t.read_set = [("", 0)]
                        txns.append(t)

                apply_idx = _concat_ranges(flat.wr_start[win], flat.wr_len[win])
                rows = flat.wr_row[apply_idx]
                has_writes = flat.wr_len[win] > 0
                bufs = np.fromiter(
                    (self.engine.buffer_for(int(w)).id for w in workers[win]),
                    np.int64, len(win),
                )
                ssns = np.array(bases)  # read-only winners: ssn = base

                # phase 1 — log side, one buffer at a time: reserve, encode,
                # publish.  Each buffer's reservation is filled before the
                # next buffer is touched, so a failure (space-wait timeout)
                # never leaves an unfillable hole behind — at worst the log
                # runs ahead of the in-memory table (standard WAL property;
                # the affected txns are committed-but-unacknowledged).  The
                # only deterministic failure, a per-buffer batch bigger than
                # the ring, is pre-checked before any reservation.
                write_bufs = np.unique(bufs[has_writes]).tolist()
                for buf_id in write_bufs:
                    sel = np.flatnonzero(has_writes & (bufs == buf_id))
                    total = int(flat.rec_len[win[sel]].sum())
                    cap = self.engine.buffers[buf_id].capacity
                    if total > cap:
                        raise ValueError(
                            f"batch needs {total}B on buffer {buf_id} "
                            f"(> capacity {cap}B); reduce the batch size"
                        )
                if _trace:
                    # sequence span: base SSNs + Txn bookkeeping + buffer
                    # routing (everything between the masks and the first
                    # reserve), so consecutive spans tile the round
                    TRACER.record(
                        ST_SEQUENCE, shard=self.trace_shard, batch=_bid,
                        t0=_tv1, t1=time.perf_counter(), n_txn=len(win),
                    )
                for buf_id in write_bufs:
                    if _trace:
                        _te0 = time.perf_counter()
                    sel = np.flatnonzero(has_writes & (bufs == buf_id))
                    b_ssns, b_offs, seg = self.engine.buffers[buf_id].reserve_batch(
                        bases[sel], flat.rec_len[win[sel]]
                    )
                    ssns[sel] = b_ssns
                    group = [txns[k] for k in sel.tolist()]
                    for t, s in zip(group, b_ssns.tolist()):
                        t.ssn = s
                        t.buffer_id = buf_id
                    if specs is not None:
                        blob, lens = encode_batch(group)
                    else:
                        # columnar framing straight from the arrays: keys
                        # and key lengths come from the table's columns
                        gw = win[sel]
                        g_idx = _concat_ranges(flat.wr_start[gw], flat.wr_len[gw])
                        g_rows = flat.wr_row[g_idx]
                        blob, lens = encode_batch_columns(
                            b_ssns,
                            np.fromiter(
                                (t.tid for t in group), np.int64, len(group)
                            ),
                            np.where(flat.rd_len[gw] > 0, FLAG_HAS_READS, 0
                                     ).astype(np.uint8),
                            flat.wr_len[gw],
                            table.key_bytes_for(g_rows.tolist()),
                            flat.wr_vals[g_idx],
                            klen=table.key_len[g_rows],
                            vlen=flat.wr_vlen[g_idx],
                        )
                    # same guard as the scalar publish(): the reserved slots
                    # came from _Flat's analytic lengths — drift would
                    # corrupt every later record in the segment
                    assert np.array_equal(lens, flat.rec_len[win[sel]]), (
                        "framed length drift between _Flat and encode"
                    )
                    if REGISTRY.enabled:
                        cm = flat.is_cmd[win[sel]]
                        n_cmd = int(cm.sum())
                        cb = int(lens[cm].sum())
                        REGISTRY.count("adaptive.log_bytes_command", cb)
                        REGISTRY.count("adaptive.log_bytes_value",
                                       int(lens.sum()) - cb)
                        REGISTRY.count("adaptive.policy.command", n_cmd)
                        REGISTRY.count("adaptive.policy.value",
                                       len(group) - n_cmd)
                    if _trace:
                        TRACER.record(
                            ST_ENCODE, shard=self.trace_shard,
                            device=buf_id, batch=_bid,
                            txn_lo=int(b_ssns[0]), txn_hi=int(b_ssns[-1]),
                            t0=_te0, t1=time.perf_counter(),
                            nbytes=len(blob), n_txn=len(group),
                        )
                    self.engine.publish_batch(
                        group, blob, buffer_id=buf_id,
                        offset=int(b_offs[0]), seg_idx=seg,
                    )

                # phase 2 — table write-back under claimed locks: values +
                # SSNs as two scatters (intra-txn duplicate keys resolve
                # last-write-wins, like the scalar apply loop); the finally
                # guarantees the locks can't wedge the rows
                if _trace:
                    _tw0 = time.perf_counter()
                tids = np.fromiter((t.tid for t in txns), np.int64, len(txns))
                table.claim_rows(rows, np.repeat(tids, flat.wr_len[win]))
                try:
                    table.values[rows] = flat.wr_vals[apply_idx]
                    table.ssn[rows] = np.repeat(ssns, flat.wr_len[win])
                finally:
                    table.release_rows(rows)
                ro = np.flatnonzero(~has_writes)
                if len(ro):
                    for k in ro.tolist():
                        txns[k].ssn = int(ssns[k])
                    self.engine.publish_batch([txns[k] for k in ro.tolist()])
                if _trace:
                    TRACER.record(
                        ST_WRITEBACK, shard=self.trace_shard, batch=_bid,
                        t0=_tw0, t1=time.perf_counter(), n_txn=len(txns),
                    )

            res.committed.extend(txns)
            res.committed_idx.extend(win.tolist())
            self.committed_submitted += len(txns)
            active = active[~survive]

        if _trace:
            TRACER.ctx.batch = -1
        res.aborted = active.tolist()
        return res

    def drain(self) -> int:
        n = 0
        for w in range(self.n_workers):
            n += self.engine.drain(self.worker_id_base + w)
        return n


class ScalarBatchOCC:
    """Per-transaction oracle for :class:`BatchOCC` (recovery's
    ``mode="scalar"`` pattern): identical batch semantics — reads observed at
    round start, first-come-wins against *all* of the round's write intents,
    driver-observed SSN validation — executed serially with the existing
    scalar machinery (dict ``Table`` cells, per-txn ``engine.allocate`` +
    ``Txn`` writeback + ``engine.publish``).  Runs single-threaded, so
    per-tuple locks are not taken; foreign-lock behaviour is out of scope
    for the oracle."""

    def __init__(
        self,
        table: Table,
        engine: LoggingEngine,
        n_workers: int = 1,
        tid_stride: int = TID_STRIDE,
    ):
        self.table = table
        self.engine = engine
        self.n_workers = n_workers
        self.stripes = [TidStripe(w, tid_stride) for w in range(n_workers)]
        for w in range(n_workers):
            engine.register_worker(w)
        self.committed_submitted = 0
        self.aborts = 0

    def execute_batch(
        self,
        specs: Sequence[TxnSpec],
        worker_ids: Optional[Sequence[int]] = None,
        max_rounds: int = 1,
    ) -> BatchResult:
        b = len(specs)
        res = BatchResult()
        if worker_ids is None:
            worker_ids = [i % self.n_workers for i in range(b)]
        t_start = time.perf_counter()

        active = list(range(b))
        while active and res.rounds < max_rounds:
            res.rounds += 1
            first_writer: Dict[str, int] = {}
            for i in active:
                for k, _ in specs[i].writes:
                    first_writer.setdefault(k, i)
            observed = {}
            for i in active:
                observed[i] = [
                    self.table.get_or_insert(k).ssn for k in specs[i].reads
                ]
                for k, _ in specs[i].writes:
                    # materialize write cells like the scalar read phase does
                    # (the flattened path inserts all accessed keys up front)
                    self.table.get_or_insert(k)
            winners: List[int] = []
            for i in active:
                spec = specs[i]
                ok = all(
                    first_writer.get(k, b) >= i
                    for k in list(spec.reads) + [k for k, _ in spec.writes]
                )
                if ok and spec.observed is not None:
                    ok = all(
                        self.table.get_or_insert(k).ssn == int(o)
                        for k, o in zip(spec.reads, spec.observed)
                    )
                if not ok:
                    self.aborts += 1
                    continue
                w = worker_ids[i]
                cells_r = [self.table.get_or_insert(k) for k in spec.reads]
                cells_w = [self.table.get_or_insert(k) for k, _ in spec.writes]
                txn = Txn(tid=self.stripes[w].next())
                txn.worker_id = w  # type: ignore[attr-defined]
                txn.t_start = t_start
                txn.read_set = [(k, o) for k, o in zip(spec.reads, observed[i])]
                txn.write_set = list(spec.writes)
                self.engine.allocate(txn, cells_r, cells_w)
                for cell, (_, val) in zip(cells_w, spec.writes):
                    cell.value = val
                if txn.write_set:
                    ssn_mod.writeback(txn.ssn, cells_w)
                self.engine.publish(txn)
                winners.append(i)
                res.committed.append(txn)
                res.committed_idx.append(i)
            self.committed_submitted += len(winners)
            if not winners:
                break
            won = set(winners)
            active = [i for i in active if i not in won]

        res.aborted = list(active)
        return res

    def drain(self) -> int:
        n = 0
        for w in range(self.n_workers):
            n += self.engine.drain(w)
        return n
