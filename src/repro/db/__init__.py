"""In-memory DB substrate: tuple store, OCC (section 4.4), YCSB/TPC-C workloads."""

from .table import Table, TupleCell
from .occ import OCCWorker

__all__ = ["Table", "TupleCell", "OCCWorker"]
