"""In-memory DB substrate: tuple store, OCC (section 4.4), YCSB/TPC-C workloads.

Two execution substrates share the flat key space:

* scalar — dict :class:`Table` of :class:`TupleCell` + per-txn
  :class:`OCCWorker` (one transaction at a time, per-tuple locks);
* batched — columnar :class:`ArrayTable` + :class:`BatchOCC` (whole batches
  validated/sequenced/encoded with array ops; :class:`ScalarBatchOCC` is the
  equivalence oracle).
"""

from .array_table import ArrayTable
from .batch import BatchOCC, BatchResult, ScalarBatchOCC, TxnSpec
from .occ import OCCWorker, TidStripe, TID_STRIDE
from .table import Table, TupleCell

__all__ = [
    "ArrayTable",
    "BatchOCC",
    "BatchResult",
    "ScalarBatchOCC",
    "TxnSpec",
    "OCCWorker",
    "TidStripe",
    "TID_STRIDE",
    "Table",
    "TupleCell",
]
