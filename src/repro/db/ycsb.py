"""YCSB workloads (paper §6.2), scaled for this container.

* dataset: single table, primary key + 10 columns of 100 B each.
* write-only: each txn updates all 10 columns of one uniformly-random key.
* hybrid: each txn updates one column of one key + key-range scan of fixed
  length (the scan length controls the RAW/WAR dependency mix — Fig. 10).

The paper loads 10 M rows and runs 10 M txns; defaults here are scaled down
(100 K rows) since throughput *ratios* between logging variants are the
reproduction target (DESIGN §9).
"""

from __future__ import annotations

import random
from typing import Iterator, List, Optional, Tuple

from .occ import OCCWorker
from .table import Table

N_COLS = 10
COL_BYTES = 100


def key_of(i: int) -> str:
    return f"user{i:010d}"


def load(table: Table, n_records: int = 100_000, seed: int = 7) -> None:
    rng = random.Random(seed)
    for i in range(n_records):
        table.insert(key_of(i), rng.randbytes(N_COLS * COL_BYTES))


class YCSBWriteOnly:
    """Write-only workload: update all columns of one tuple."""

    def __init__(self, n_records: int, seed: int = 0):
        self.n_records = n_records
        self.rng = random.Random(seed)

    def next_txn(self, worker: OCCWorker):
        key = key_of(self.rng.randrange(self.n_records))
        value = self.rng.randbytes(N_COLS * COL_BYTES)
        return worker.execute(reads=[], writes=[(key, value)])


class YCSBHybrid:
    """Hybrid workload: one single-column write + a fixed-length scan."""

    def __init__(self, n_records: int, scan_length: int = 10, seed: int = 0):
        self.n_records = n_records
        self.scan_length = scan_length
        self.rng = random.Random(seed)

    def next_txn(self, worker: OCCWorker):
        wkey = key_of(self.rng.randrange(self.n_records))
        value = self.rng.randbytes(COL_BYTES)  # one column
        scans = []
        if self.scan_length > 0:
            start = key_of(self.rng.randrange(self.n_records))
            scans.append((start, self.scan_length))
        return worker.execute(reads=[], writes=[(wkey, value)], scans=scans)
