"""YCSB workloads (paper §6.2), scaled for this container.

* dataset: single table, primary key + 10 columns of 100 B each.
* write-only: each txn updates all 10 columns of one uniformly-random key.
* hybrid: each txn updates one column of one key + key-range scan of fixed
  length (the scan length controls the RAW/WAR dependency mix — Fig. 10).

The paper loads 10 M rows and runs 10 M txns; defaults here are scaled down
(100 K rows) since throughput *ratios* between logging variants are the
reproduction target (DESIGN §9).
"""

from __future__ import annotations

import random
from typing import Iterator, List, Optional, Tuple

import numpy as np

from .batch import TxnSpec
from .occ import OCCWorker
from .table import Table

N_COLS = 10
COL_BYTES = 100


def key_of(i: int) -> str:
    return f"user{i:010d}"


class Zipfian:
    """YCSB-standard Zipfian key-index generator (Gray et al., "Quickly
    Generating Billion-Record Synthetic Databases"): item rank ``r`` is
    drawn with probability ∝ ``1 / r^theta`` over ``[0, n)``.  ``theta``
    0.99 is the YCSB default; 0 degenerates to uniform.

    The ``zeta(n)`` normalizer is the one O(n) cost, paid once at
    construction; draws are O(1).  :meth:`sample` is the vectorized batch
    twin (same closed form applied to a uniform array — used by the batch
    workload generators), :meth:`next` the scalar single-txn draw open-loop
    clients use.  Rank→item identity is left as-is (rank 0 = item 0): the
    serving-tier skew tests want a *known* hottest key, and callers that
    need scrambled placement can permute indices themselves.
    """

    def __init__(self, n: int, theta: float = 0.99, seed: int = 0):
        assert n >= 2 and 0.0 <= theta < 1.0, "need n >= 2, 0 <= theta < 1"
        self.n = n
        self.theta = theta
        self.rng = np.random.default_rng(seed)
        ranks = np.arange(1, n + 1, dtype=np.float64)
        self.zetan = float(np.sum(ranks ** -theta))
        self.zeta2 = 1.0 + 2.0 ** -theta
        self.alpha = 1.0 / (1.0 - theta)
        self.eta = (1.0 - (2.0 / n) ** (1.0 - theta)) / (
            1.0 - self.zeta2 / self.zetan
        )

    def sample(self, size: int) -> np.ndarray:
        """``size`` zipfian item indices in ``[0, n)`` (vectorized)."""
        u = self.rng.random(size)
        uz = u * self.zetan
        spread = self.n * (self.eta * u - self.eta + 1.0) ** self.alpha
        idx = np.where(
            uz < 1.0, 0, np.where(uz < self.zeta2, 1, spread.astype(np.int64))
        )
        return np.minimum(idx.astype(np.int64), self.n - 1)

    def next(self) -> int:
        return int(self.sample(1)[0])


def load(table, n_records: int = 100_000, seed: int = 7) -> None:
    """Populate ``table`` — any store with ``insert(key, value)``, i.e. the
    dict :class:`Table` or the columnar ``ArrayTable`` interchangeably."""
    rng = random.Random(seed)
    for i in range(n_records):
        table.insert(key_of(i), rng.randbytes(N_COLS * COL_BYTES))


class YCSBWriteOnly:
    """Write-only workload: update all columns of one tuple.

    ``theta > 0`` switches key selection from uniform to Zipfian skew
    (hot-key contention — the serving tier's retry-under-skew workload);
    0.0 keeps the original uniform draw, byte-compatible with old seeds.
    """

    def __init__(self, n_records: int, seed: int = 0, theta: float = 0.0):
        self.n_records = n_records
        self.rng = random.Random(seed)
        self._vrng = np.random.default_rng(seed)  # C-speed value payloads
        self.zipf = Zipfian(n_records, theta, seed=seed) if theta > 0 else None

    def _key_indices(self, n: int) -> np.ndarray:
        if self.zipf is not None:
            return self.zipf.sample(n)
        return self._vrng.integers(0, self.n_records, n)

    def next_txn(self, worker: OCCWorker):
        i = self.zipf.next() if self.zipf else self.rng.randrange(self.n_records)
        value = self.rng.randbytes(N_COLS * COL_BYTES)
        return worker.execute(reads=[], writes=[(key_of(i), value)])

    def next_batch(self, n: int) -> List[TxnSpec]:
        """``n`` write-only txn specs for the batched executor
        (`repro.db.batch.BatchOCC`).  Generation is itself batched: one
        value-blob draw sliced per txn, one vectorized key-index draw."""
        nbytes = N_COLS * COL_BYTES
        blob = self._vrng.bytes(n * nbytes)
        idx = self._key_indices(n)
        return [
            TxnSpec(writes=[(key_of(k), blob[i * nbytes : (i + 1) * nbytes])])
            for i, k in enumerate(idx.tolist())
        ]

    def next_specs(self, n: int) -> List[TxnSpec]:
        """Alias of :meth:`next_batch` under the serving-tier name: open-loop
        clients pre-draw ``n`` single-txn specs and submit them one at a
        time, so "a batch of specs" and "n client arrivals" are the same
        draw."""
        return self.next_batch(n)

    def next_batch_indexed(self, n: int):
        """The same batch as index arrays for ``BatchOCC.execute_indexed``:
        ``(rd_row, rd_start, wr_key_idx, wr_start, values, vlen)``.  Key
        indices equal ArrayTable rows when the table was populated by
        :func:`load` (keys inserted in index order)."""
        nbytes = N_COLS * COL_BYTES
        blob = self._vrng.bytes(n * nbytes)
        wr_row = self._vrng.integers(0, self.n_records, n)
        starts = np.arange(n + 1, dtype=np.int64)
        values = [blob[i * nbytes : (i + 1) * nbytes] for i in range(n)]
        vlen = np.full(n, nbytes, dtype=np.int64)
        return (np.empty(0, np.int64), np.zeros(n + 1, np.int64),
                wr_row.astype(np.int64), starts, values, vlen)


class RMWSpecFactory:
    """Read-modify-write specs for the serving tier's retry path.

    Each generated closure reads one (optionally Zipfian-hot) key, records
    the tuple SSN observed *at build time*, and writes a value derived from
    the read.  The executor validates the observed SSN, so a spec built
    before a conflicting winner commits loses validation — exactly the abort
    the scheduler's retry-with-backoff must absorb.  The scheduler re-invokes
    the closure on retry, which re-reads the now-current value/SSN, so a
    retried transaction eventually wins.
    """

    def __init__(
        self,
        table,
        n_records: int,
        seed: int = 0,
        theta: float = 0.99,
    ):
        self.table = table  # dict Table (cells) or ArrayTable ((value, ssn))
        self.n_records = n_records
        self.rng = random.Random(seed)
        self.zipf = Zipfian(n_records, theta, seed=seed) if theta > 0 else None

    def _observe(self, key: str) -> Tuple[bytes, int]:
        got = self.table.get_or_insert(key)
        if isinstance(got, tuple):
            return got
        return got.value, got.ssn

    def spec_fn(self):
        """One client transaction: a zero-arg closure over a freshly drawn
        key, usable as ``GroupCommitScheduler.submit(make_spec)`` — every
        invocation (first attempt and each retry) re-reads the key."""
        i = self.zipf.next() if self.zipf else self.rng.randrange(self.n_records)
        key = key_of(i)

        def build() -> TxnSpec:
            value, ssn = self._observe(key)
            head = bytes(b ^ 0xFF for b in value[:COL_BYTES])
            return TxnSpec(
                reads=[key],
                writes=[(key, head + value[COL_BYTES:])],
                observed=[ssn],
            )

        return build


class AdaptiveRMW:
    """Batched read-modify-write specs carrying command framing fields
    (adaptive logging, `repro.core.engine.AdaptivePolicy`).

    Two shapes, selected by ``op``:

    * ``"patch"`` — YCSB-style field update: read one wide tuple, overwrite
      the leading column, keep the tail.  Ships ``(OP_PATCH_PREFIX, new
      head)`` — ``COL_BYTES`` of param against ``N_COLS * COL_BYTES`` of
      tuple, the paper-motivating command-framing win;
    * ``"add_f64"`` — TPC-C-payment-style balance delta: the tuple is a
      little-endian float64 plus an opaque tail, the param the 8-byte delta
      (``OP_ADD_F64``).

    Each spec's write value is the exact post-image the registered op
    re-derives from ``(pre-image, param)`` — the executor applies the value,
    replay re-executes the command, and crash equivalence holds either way.
    Keys are drawn *without replacement per batch* so specs built against
    the same table snapshot never invalidate each other mid-batch.
    """

    def __init__(self, table, n_records: int, seed: int = 0,
                 op: str = "patch"):
        if op not in ("patch", "add_f64"):
            raise ValueError(f"unknown AdaptiveRMW op {op!r}")
        from ..core.command import OP_ADD_F64, OP_PATCH_PREFIX
        self.table = table
        self.n_records = n_records
        self.op = op
        self.op_id = OP_PATCH_PREFIX if op == "patch" else OP_ADD_F64
        self._rng = np.random.default_rng(seed)

    def next_batch(self, n: int) -> List[TxnSpec]:
        import struct as _struct
        n = min(n, self.n_records)
        idx = self._rng.choice(self.n_records, size=n, replace=False)
        specs: List[TxnSpec] = []
        for i in idx.tolist():
            key = key_of(i)
            value, ssn = self.table.get(key)
            if self.op == "patch":
                param = bytes(b ^ 0xFF for b in value[:COL_BYTES])
                new = param + value[COL_BYTES:]
            else:
                delta = float(self._rng.integers(1, 500)) / 100.0
                param = _struct.pack("<d", delta)
                old = _struct.unpack_from("<d", value)[0] if len(value) >= 8 else 0.0
                new = _struct.pack("<d", old + delta) + value[8:]
            specs.append(TxnSpec(
                reads=[key], writes=[(key, new)], observed=[ssn],
                cmd_op=self.op_id, cmd_params=[param],
            ))
        return specs


class YCSBHybrid:
    """Hybrid workload: one single-column write + a fixed-length scan."""

    def __init__(self, n_records: int, scan_length: int = 10, seed: int = 0):
        self.n_records = n_records
        self.scan_length = scan_length
        self.rng = random.Random(seed)

    def next_txn(self, worker: OCCWorker):
        wkey = key_of(self.rng.randrange(self.n_records))
        value = self.rng.randbytes(COL_BYTES)  # one column
        scans = []
        if self.scan_length > 0:
            start = key_of(self.rng.randrange(self.n_records))
            scans.append((start, self.scan_length))
        return worker.execute(reads=[], writes=[(wkey, value)], scans=scans)

    def next_batch(self, n: int) -> List[TxnSpec]:
        """Batched hybrid specs: the key-range scan expands to explicit point
        reads (YCSB keys are fixed-format, so logical order == key order —
        the same assumption ``Table.scan_range`` makes)."""
        rng = self.rng
        out: List[TxnSpec] = []
        for _ in range(n):
            wkey = key_of(rng.randrange(self.n_records))
            start = rng.randrange(self.n_records)
            reads = [
                key_of(j)
                for j in range(start, min(start + self.scan_length, self.n_records))
            ]
            out.append(TxnSpec(reads=reads, writes=[(wkey, rng.randbytes(COL_BYTES))]))
        return out
