"""YCSB workloads (paper §6.2), scaled for this container.

* dataset: single table, primary key + 10 columns of 100 B each.
* write-only: each txn updates all 10 columns of one uniformly-random key.
* hybrid: each txn updates one column of one key + key-range scan of fixed
  length (the scan length controls the RAW/WAR dependency mix — Fig. 10).

The paper loads 10 M rows and runs 10 M txns; defaults here are scaled down
(100 K rows) since throughput *ratios* between logging variants are the
reproduction target (DESIGN §9).
"""

from __future__ import annotations

import random
from typing import Iterator, List, Optional, Tuple

import numpy as np

from .batch import TxnSpec
from .occ import OCCWorker
from .table import Table

N_COLS = 10
COL_BYTES = 100


def key_of(i: int) -> str:
    return f"user{i:010d}"


def load(table, n_records: int = 100_000, seed: int = 7) -> None:
    """Populate ``table`` — any store with ``insert(key, value)``, i.e. the
    dict :class:`Table` or the columnar ``ArrayTable`` interchangeably."""
    rng = random.Random(seed)
    for i in range(n_records):
        table.insert(key_of(i), rng.randbytes(N_COLS * COL_BYTES))


class YCSBWriteOnly:
    """Write-only workload: update all columns of one tuple."""

    def __init__(self, n_records: int, seed: int = 0):
        self.n_records = n_records
        self.rng = random.Random(seed)
        self._vrng = np.random.default_rng(seed)  # C-speed value payloads

    def next_txn(self, worker: OCCWorker):
        key = key_of(self.rng.randrange(self.n_records))
        value = self.rng.randbytes(N_COLS * COL_BYTES)
        return worker.execute(reads=[], writes=[(key, value)])

    def next_batch(self, n: int) -> List[TxnSpec]:
        """``n`` write-only txn specs for the batched executor
        (`repro.db.batch.BatchOCC`).  Generation is itself batched: one
        value-blob draw sliced per txn, one vectorized key-index draw."""
        nbytes = N_COLS * COL_BYTES
        blob = self._vrng.bytes(n * nbytes)
        idx = self._vrng.integers(0, self.n_records, n)
        return [
            TxnSpec(writes=[(key_of(k), blob[i * nbytes : (i + 1) * nbytes])])
            for i, k in enumerate(idx.tolist())
        ]

    def next_batch_indexed(self, n: int):
        """The same batch as index arrays for ``BatchOCC.execute_indexed``:
        ``(rd_row, rd_start, wr_key_idx, wr_start, values, vlen)``.  Key
        indices equal ArrayTable rows when the table was populated by
        :func:`load` (keys inserted in index order)."""
        nbytes = N_COLS * COL_BYTES
        blob = self._vrng.bytes(n * nbytes)
        wr_row = self._vrng.integers(0, self.n_records, n)
        starts = np.arange(n + 1, dtype=np.int64)
        values = [blob[i * nbytes : (i + 1) * nbytes] for i in range(n)]
        vlen = np.full(n, nbytes, dtype=np.int64)
        return (np.empty(0, np.int64), np.zeros(n + 1, np.int64),
                wr_row.astype(np.int64), starts, values, vlen)


class YCSBHybrid:
    """Hybrid workload: one single-column write + a fixed-length scan."""

    def __init__(self, n_records: int, scan_length: int = 10, seed: int = 0):
        self.n_records = n_records
        self.scan_length = scan_length
        self.rng = random.Random(seed)

    def next_txn(self, worker: OCCWorker):
        wkey = key_of(self.rng.randrange(self.n_records))
        value = self.rng.randbytes(COL_BYTES)  # one column
        scans = []
        if self.scan_length > 0:
            start = key_of(self.rng.randrange(self.n_records))
            scans.append((start, self.scan_length))
        return worker.execute(reads=[], writes=[(wkey, value)], scans=scans)

    def next_batch(self, n: int) -> List[TxnSpec]:
        """Batched hybrid specs: the key-range scan expands to explicit point
        reads (YCSB keys are fixed-format, so logical order == key order —
        the same assumption ``Table.scan_range`` makes)."""
        rng = self.rng
        out: List[TxnSpec] = []
        for _ in range(n):
            wkey = key_of(rng.randrange(self.n_records))
            start = rng.randrange(self.n_records)
            reads = [
                key_of(j)
                for j in range(start, min(start + self.scan_length, self.n_records))
            ]
            out.append(TxnSpec(reads=reads, writes=[(wkey, rng.randbytes(COL_BYTES))]))
        return out
