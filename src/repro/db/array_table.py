"""Dense columnar tuple store — the array-native twin of :class:`Table`.

Where :class:`~repro.db.table.Table` keeps one :class:`TupleCell` object per
key (with a per-tuple ``threading.Lock``), ``ArrayTable`` holds the same
state as struct-of-arrays over dense integer rows:

* ``ssn``        — int64 per-tuple sequence numbers (Algorithm 1 state);
* ``lock_owner`` — int64 write-lock owner tids (0 = free), maintained
  vectorized so batch validation can test/claim whole index arrays;
* ``values``     — object array of value bytes.

A ``key -> row`` dict maps the flat key space onto rows; rows are append
-only and never reused, so an index array gathered once stays valid for the
life of the table.  This is the substrate of the batched OCC executor
(`repro.db.batch`): validation, SSN base computation, and write-back are
all gathers/scatters over these columns — the per-tuple lock round-trips of
the scalar path collapse into a handful of array ops under one mutex.

The layout deliberately mirrors the columnar *log* layout
(:class:`~repro.core.txn.ColumnarLog`) that recovery decodes: the same
(key, value, ssn) triple flows from execution through logging to replay
without leaving array form.
"""

from __future__ import annotations

import threading
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .table import Table


class ArrayTable:
    """A flat key space over dense columnar rows (batched forward path)."""

    def __init__(self, capacity: int = 1024, name: str = "main"):
        self.name = name
        capacity = max(capacity, 1)
        self._index: Dict[str, int] = {}
        self._keys: List[str] = []
        self._keys_b: List[bytes] = []   # encoded key bytes (log framing)
        self.ssn = np.zeros(capacity, dtype=np.int64)
        self.lock_owner = np.zeros(capacity, dtype=np.int64)
        self.key_len = np.zeros(capacity, dtype=np.int64)  # len(encoded key)
        self.values = np.empty(capacity, dtype=object)
        # one mutex guards structural growth and the vectorized
        # claim/apply/release critical sections of the batch executor
        self.mutex = threading.Lock()

    # --- rows ----------------------------------------------------------------
    @property
    def n(self) -> int:
        return len(self._keys)

    def __len__(self) -> int:
        return len(self._keys)

    def __contains__(self, key: str) -> bool:
        return key in self._index

    def _grow(self, need: int) -> None:
        cap = len(self.ssn)
        if need <= cap:
            return
        new_cap = max(need, cap * 2)
        for name in ("ssn", "lock_owner", "key_len", "values"):
            old = getattr(self, name)
            arr = np.zeros(new_cap, old.dtype) if old.dtype != object else np.empty(new_cap, object)
            arr[:cap] = old
            setattr(self, name, arr)

    def insert(self, key: str, value: bytes) -> int:
        """Upsert one key; returns its row (``Table.insert`` duck-type, so
        the YCSB/TPC-C loaders work unchanged against either store)."""
        with self.mutex:
            row = self._index.get(key)
            if row is None:
                row = self._insert_locked(key)
            self.values[row] = value
            return row

    def _insert_locked(self, key: str, kb: Optional[bytes] = None) -> int:
        row = len(self._keys)
        self._grow(row + 1)
        self._index[key] = row
        self._keys.append(key)
        kb = key.encode() if kb is None else kb
        self._keys_b.append(kb)
        self.key_len[row] = len(kb)
        self.values[row] = b""
        return row

    def rows_for(self, keys: Sequence[str]) -> np.ndarray:
        """Map keys to rows, inserting missing ones (batched
        ``get_or_insert``).  Returns an int64 index array."""
        index = self._index
        out = np.empty(len(keys), dtype=np.int64)
        missing: List[Tuple[int, str]] = []
        for i, k in enumerate(keys):
            row = index.get(k)
            if row is None:
                missing.append((i, k))
                out[i] = -1
            else:
                out[i] = row
        if missing:
            with self.mutex:
                for i, k in missing:
                    row = index.get(k)
                    out[i] = self._insert_locked(k) if row is None else row
        return out

    def rows_for_bytes(self, keys: Sequence[bytes]) -> np.ndarray:
        """Map exact key *bytes* to rows, inserting missing ones — the
        replica-apply entry (`repro.replica`), where keys arrive as decoded
        log bytes rather than workload strings.  The string index entry is
        the utf-8/surrogateescape decoding: for any key a workload wrote
        through the string API it equals that string exactly (``insert``
        frames keys as utf-8), so replica point reads find it, and the
        escape round-trip keeps the mapping injective for arbitrary bytes.
        :attr:`key_bytes_for`/:meth:`to_dict` keep the exact original
        bytes."""
        index = self._index
        out = np.empty(len(keys), dtype=np.int64)
        missing: List[Tuple[int, str, bytes]] = []
        for i, kb in enumerate(keys):
            k = kb.decode("utf-8", "surrogateescape")
            row = index.get(k)
            if row is None:
                missing.append((i, k, kb))
                out[i] = -1
            else:
                out[i] = row
        if missing:
            with self.mutex:
                for i, k, kb in missing:
                    row = index.get(k)
                    out[i] = self._insert_locked(k, kb) if row is None else row
        return out

    def upsert_bytes(
        self, keys: Sequence[bytes], vals: np.ndarray, ssns: np.ndarray
    ) -> None:
        """Guarded batch upsert by exact key bytes: each (key, value, ssn)
        lands iff its SSN strictly exceeds the row's current one (the
        last-writer-wins replay guard).  Row inserts and the fold happen
        under **one** :attr:`mutex` hold, so a concurrent reader can never
        observe a freshly-inserted phantom row (``b""``, ssn 0) or a torn
        (value, ssn) pair — this is the replica applier's fold primitive."""
        with self.mutex:
            rows = np.empty(len(keys), dtype=np.int64)
            fresh = np.zeros(len(keys), dtype=bool)
            index = self._index
            for i, kb in enumerate(keys):
                k = kb.decode("utf-8", "surrogateescape")
                row = index.get(k)
                if row is None:
                    rows[i] = self._insert_locked(k, kb)
                    fresh[i] = True
                else:
                    rows[i] = row
            # a freshly-inserted row always takes the write: its placeholder
            # (b"", ssn 0) would otherwise win the strict guard against an
            # ssn-0 upsert — exactly the shape of a full-image checkpoint
            # row for a key loaded before any logged write touched it
            upd = fresh | (ssns > self.ssn[rows])
            if upd.any():
                self.ssn[rows[upd]] = ssns[upd]
                self.values[rows[upd]] = vals[upd]

    def row_of(self, key: str) -> Optional[int]:
        return self._index.get(key)

    def key_of(self, row: int) -> str:
        return self._keys[row]

    def key_bytes_for(self, rows: Sequence[int]) -> List[bytes]:
        """Encoded key bytes for ``rows`` (log-record framing: the indexed
        batch pipeline encodes keys straight from this column)."""
        kb = self._keys_b
        return [kb[r] for r in rows]

    # --- point access (tests / drivers) -------------------------------------
    def get(self, key: str) -> Optional[Tuple[bytes, int]]:
        """(value, ssn) of ``key``, or None — the batch drivers' read hook."""
        row = self._index.get(key)
        if row is None:
            return None
        return self.values[row], int(self.ssn[row])

    def get_or_insert(self, key: str) -> Tuple[bytes, int]:
        row = self._index.get(key)
        if row is None:
            with self.mutex:
                row = self._index.get(key)
                if row is None:
                    row = self._insert_locked(key)
        return self.values[row], int(self.ssn[row])

    # --- vectorized locks (batch validation) ---------------------------------
    def locked_rows(self, rows: np.ndarray, owner: int = 0) -> np.ndarray:
        """Boolean mask of ``rows`` held by a *different* owner."""
        held = self.lock_owner[rows]
        return (held != 0) & (held != owner)

    def claim_rows(self, rows: np.ndarray, owner) -> None:
        """Take the write locks for ``rows`` — ``owner`` is a tid or a
        per-row tid array (caller holds :attr:`mutex` and has verified the
        rows free via :meth:`locked_rows`)."""
        self.lock_owner[rows] = owner

    def release_rows(self, rows: np.ndarray) -> None:
        self.lock_owner[rows] = 0

    # --- interop ------------------------------------------------------------
    @classmethod
    def from_table(cls, table: Table) -> "ArrayTable":
        """Columnarize a dict :class:`Table` (cells copied, locks reset)."""
        out = cls(capacity=max(len(table), 1), name=table.name)
        for key in table.sorted_keys():
            cell = table.get(key)
            row = out._insert_locked(key)
            out.values[row] = cell.value
            out.ssn[row] = cell.ssn
        return out

    def items(self) -> Iterator[Tuple[str, bytes, int]]:
        for key, row in self._index.items():
            yield key, self.values[row], int(self.ssn[row])

    def to_dict(self) -> Dict[bytes, Tuple[bytes, int]]:
        """``key_bytes -> (value, ssn)`` — the :class:`RecoveredState.data`
        shape, for direct comparison against a post-crash recovery."""
        return {
            self._keys_b[row]: (self.values[row], int(self.ssn[row]))
            for row in self._index.values()
        }
