"""Optimistic concurrency control with SSN commit timestamps (paper §4.4).

Three phases per transaction:

* **read** — no locks; read-set entries record (cell, observed ssn, value);
  writes buffered in a private write set.
* **validation** — lock the write set in primary-key order (fixed order =>
  deadlock-free, as in Silo/TicToc); validate the read set: abort if a tuple
  is locked by another transaction or its SSN changed; on success allocate
  the SSN via the logging engine (Algorithm 1) — the SSN doubles as the
  commit timestamp, replacing a centralized timestamp allocator.
* **write** — apply new values + the SSN to the tuples, release locks
  (early lock release: incoming readers may observe pre-committed data —
  recoverability guarantees they commit after us), publish the log record,
  enqueue for commit.

``execute`` returns the pre-committed Txn (durable commit happens when the
engine's commit protocol drains it) or None if aborted.
"""

from __future__ import annotations

import time
from typing import List, Optional, Sequence, Tuple

from ..core import ssn as ssn_mod
from ..core.engine import LoggingEngine
from ..core.txn import Txn
from .table import Table, TupleCell

# default tid stripe width: tids are striped ``worker_id + 1 + k * stride``,
# so allocation is lock-free per worker and globally collision-free for any
# worker count below the stride — the global next_tid() lock of the original
# implementation would otherwise serialize the batched path
TID_STRIDE = 1024


class TidStripe:
    """Lock-free per-worker transaction-id allocation.

    Worker ``w`` draws from the arithmetic progression ``w + 1 + k*stride``
    (tid 0 is reserved for engine-internal records, e.g. heartbeats), so no
    two workers under the same stride can ever collide and no cross-worker
    lock is needed."""

    __slots__ = ("_next", "stride")

    def __init__(self, worker_id: int, stride: int = TID_STRIDE):
        assert 0 <= worker_id < stride, f"worker_id {worker_id} >= stride {stride}"
        self._next = worker_id + 1
        self.stride = stride

    def next(self) -> int:
        tid = self._next
        self._next += self.stride
        return tid


class OCCWorker:
    """One worker thread's OCC execution context."""

    def __init__(
        self,
        table: Table,
        engine: LoggingEngine,
        worker_id: int,
        tid_stride: int = TID_STRIDE,
    ):
        self.table = table
        self.engine = engine
        self.worker_id = worker_id
        self.tids = TidStripe(worker_id, tid_stride)
        engine.register_worker(worker_id)
        self.committed_submitted = 0
        self.aborts = 0

    # --- transaction execution ----------------------------------------------
    def execute(
        self,
        reads: Sequence[str],
        writes: Sequence[Tuple[str, bytes]],
        scans: Sequence[Tuple[str, int]] = (),
    ) -> Optional[Txn]:
        """Run one transaction; returns the pre-committed Txn or None on abort."""
        tid = self.tids.next()
        txn = Txn(tid=tid)
        txn.worker_id = self.worker_id  # type: ignore[attr-defined]
        txn.t_start = time.perf_counter()

        # --- read phase ---
        read_cells: List[Tuple[TupleCell, int]] = []
        for key in reads:
            cell = self.table.get_or_insert(key)
            read_cells.append((cell, cell.ssn))
        for start, length in scans:
            for cell in self.table.scan_range(start, length):
                read_cells.append((cell, cell.ssn))
        write_cells: List[Tuple[TupleCell, bytes]] = []
        for key, val in writes:
            cell = self.table.get_or_insert(key)
            write_cells.append((cell, val))

        # --- validation phase ---
        # lock write set in primary-key order (deadlock freedom)
        write_cells.sort(key=lambda cv: cv[0].key)
        locked: List[TupleCell] = []
        ok = True
        for cell, _ in write_cells:
            # bounded spin on try_lock: contention aborts rather than blocks
            acquired = False
            for _ in range(100):
                if cell.try_lock(tid):
                    acquired = True
                    break
            if not acquired:
                ok = False
                break
            locked.append(cell)
        if ok:
            for cell, seen_ssn in read_cells:
                if cell.locked_by_other(tid) or cell.ssn != seen_ssn:
                    ok = False
                    break
        if not ok:
            for cell in locked:
                cell.unlock(tid)
            self.aborts += 1
            txn.aborted = True
            return None

        # SSN allocation (Algorithm 1) — the commit timestamp
        txn.read_set = [(c.key, s) for c, s in read_cells]
        txn.write_set = [(c.key, v) for c, v in write_cells]
        self.engine.allocate(
            txn, [c for c, _ in read_cells], [c for c, _ in write_cells]
        )

        # --- write phase (with early lock release) ---
        for cell, val in write_cells:
            cell.value = val
        if txn.write_set:
            ssn_mod.writeback(txn.ssn, [c for c, _ in write_cells])
        for cell in locked:
            cell.unlock(tid)

        self.engine.publish(txn)
        self.committed_submitted += 1
        return txn

    def drain(self) -> int:
        return self.engine.drain(self.worker_id)
