"""Parallel log shipping — the replication ingest side.

One :class:`LogShipper` tails one log device (or file) *independently*: there
is no cross-device merge and no shipping order between devices, exactly the
paper's point that partially constrained logs need no total order — the
consumer re-derives everything it needs from SSNs (`repro.replica.applier`).

Shipping is incremental: each poll reads only the bytes past the shipper's
consumed offset (:meth:`~repro.core.storage.StorageDevice.read_from`) and
decodes only the *complete* frames among them
(:func:`~repro.core.txn.decode_columnar_stream`).  A torn trailing frame —
an append that has not fully landed, a partial flush, a length field running
past the end — is **retried, never decoded**: its bytes stay buffered in the
shipper and are re-framed once more bytes arrive.  This is the same
length+crc validation crash recovery uses to truncate a torn tail, applied
as a resumable stream, so shipped and recovered torn-tail semantics are
byte-identical.

The shipped unit is a :class:`~repro.core.txn.ColumnarLog` chunk — the same
struct-of-arrays form recovery decodes — so the applier folds it with the
vectorized replay machinery without any re-decoding.
"""

from __future__ import annotations

import os
from typing import List, Optional, Protocol, Sequence

import time

from ..core.par import parallel_for
from ..core.txn import ColumnarLog, decode_columnar_stream
from ..trace.span import ST_SHIP, TRACER
from ..obs.metrics import REGISTRY


class TailSource(Protocol):
    """Anything tailable: exposes the durable byte stream incrementally."""

    def read_from(self, offset: int) -> bytes: ...
    def size(self) -> int: ...


class FileSource:
    """A plain append-only file as a :class:`TailSource` (journal lanes)."""

    def __init__(self, path: str):
        self.path = path

    def read_from(self, offset: int) -> bytes:
        with open(self.path, "rb") as f:
            f.seek(offset)
            return f.read()

    def size(self) -> int:
        return os.path.getsize(self.path)


class LogShipper:
    """Tails one log source; each :meth:`poll` ships the new complete frames.

    State:

    * ``consumed`` — bytes fully decoded into frames so far;
    * ``frontier`` — SSN of the newest shipped durable record: this device's
      replicated DSN frontier.  ``min`` over a device set's frontiers is the
      shipped prefix's RSNe — the replica's visibility watermark
      (`repro.replica.replica.Replica.visible_ssn`);
    * the torn-tail remainder, buffered internally between polls.
    """

    def __init__(self, source: TailSource, device_id: int = 0):
        self.source = source
        self.device_id = device_id
        self.consumed = 0
        self.frontier = 0
        self.n_shipped = 0
        self.n_polls = 0
        self._tail = b""
        # shard id stamped on trace spans (set by the sharded replica)
        self.trace_shard = 0

    def poll(self) -> Optional[ColumnarLog]:
        """Ship the frames that became complete since the last poll.

        Returns None when nothing new decoded (no new bytes, or only a
        still-torn tail).  A corrupt/torn trailing frame is left in place
        and retried next poll — on a crashed primary it simply never
        completes, which is exactly recovery's truncation point.

        Raises :class:`~repro.core.storage.TruncatedLogError` (from the
        source) when the read offset predates the source's truncation point
        — the bytes this tailer still needed were dropped by the log
        truncator, and the owner must :meth:`rebase` it from a checkpoint
        (`repro.replica.replica.Replica` does this transparently).
        """
        self.n_polls += 1
        _trace = TRACER.enabled
        if _trace:
            _t0 = time.perf_counter()
        new = self.source.read_from(self.consumed + len(self._tail))
        buf = self._tail + new if self._tail else new
        if not buf:
            return None
        log, used = decode_columnar_stream(buf)
        self._tail = buf[used:]
        self.consumed += used
        if log.n_records == 0:
            return None
        self.frontier = max(self.frontier, log.last_ssn)
        self.n_shipped += log.n_records
        if _trace:
            TRACER.record(
                ST_SHIP, shard=self.trace_shard, device=self.device_id,
                txn_hi=log.last_ssn, t0=_t0, t1=time.perf_counter(),
                nbytes=used, n_txn=log.n_records,
            )
        if REGISTRY.enabled:
            REGISTRY.count("replica.ship_bytes", used)
            REGISTRY.count("replica.ship_records", log.n_records)
        return log

    def rebase(self, offset: int, ssn_floor: int) -> None:
        """Jump the tailer over a truncation hole: resume reading at
        ``offset`` (the source's truncation point) and raise the shipped
        frontier to ``ssn_floor`` (the source's ``truncated_ssn`` — every
        dropped record's SSN is at or below it).  Only sound when the owner
        has seeded the skipped records' effects from the checkpoint that
        anchored the truncation; the safe-point rule guarantees that image
        covers exactly what was dropped."""
        assert offset >= self.consumed, "rebase must move forward"
        self.consumed = offset
        self._tail = b""
        self.frontier = max(self.frontier, ssn_floor)

    def lag_bytes(self) -> int:
        """Durable bytes at the source not yet decoded (shipping backlog)."""
        return max(0, self.source.size() - self.consumed)


def ship_all(
    shippers: Sequence[LogShipper], parallel: bool = True
) -> List[Optional[ColumnarLog]]:
    """Poll every shipper — in parallel threads when ``parallel`` (devices
    are independent streams; this mirrors recovery's per-device decode
    threading)."""
    out: List[Optional[ColumnarLog]] = [None] * len(shippers)

    def _poll(i: int) -> None:
        out[i] = shippers[i].poll()

    parallel_for(len(shippers), _poll, parallel)
    return out
