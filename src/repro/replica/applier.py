"""Continuous vectorized apply — folding shipped log chunks into a live
:class:`~repro.db.array_table.ArrayTable`.

The applier is incremental crash recovery: every poll it runs the *same*
batched last-writer-wins reduction recovery uses
(:func:`~repro.core.recovery.replay_columnar`) over the not-yet-applied
shipped records, then folds the per-key winners into the table under the
per-key SSN high-water mark the table already carries (its ``ssn`` column —
a log write lands iff its SSN strictly exceeds the row's).  The carried
high-water mark is what makes incremental application exactly equal to a
one-shot replay of the whole log: re-applying a record is a no-op (strict
``>`` guard), and chunk arrival order cannot matter because order was never
encoded in the log to begin with.

Which records apply when is the paper's §5 commit guard evaluated against
the *shipped* watermark instead of the crash-time RSNe:

* write-only (Qww) records apply as soon as shipped — durable on their own
  device implies committed on the primary;
* HAS_READS (Qwr) records apply only once ``ssn <= watermark`` (the shipped
  RSNe): only then is every RAW predecessor — smaller SSN, durable in
  whichever device holds it — guaranteed shipped and applied.  Until then
  the record is **held**, so a replica read can never observe a transaction
  whose RAW predecessor is missing.

Held records stay in their decoded chunk; the chunk is re-offered to the
reduction on each poll (already-applied records masked out) and dropped
once fully applied.  An optional per-chunk ``gate`` mask injects the
cross-shard cut (`repro.replica.sharded`), exactly like recovery's
``record_mask``.

Three modes, kept equivalent (property-tested): ``vectorized`` (numpy
reduction), ``pallas`` (the scatter-max kernel apply inside
``replay_columnar``), ``scalar`` (the per-record guarded walk, the oracle).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import numpy as np

import time

from ..core.recovery import committed_mask, replay_columnar
from ..core.txn import ColumnarLog
from ..db.array_table import ArrayTable
from ..trace.span import ST_APPLY, TRACER

# per-chunk gate: None = no extra gating, else a bool mask over the chunk's
# records (the sharded cut predicate, re-evaluated as frontiers advance).
# For cross-shard (x_rec) records the gate is *authoritative* — it already
# evaluates the §5 guard per participant edge, so the applier does not also
# apply the local watermark to them.
GateFn = Callable[[ColumnarLog], Optional[np.ndarray]]

# sentinel RSNe passed to replay_columnar once the §5 guard has already been
# folded into the record mask (far above any real SSN)
_NO_GUARD = 1 << 62


@dataclass
class _Chunk:
    log: ColumnarLog
    applied: np.ndarray = field(default=None)  # type: ignore[assignment]

    def __post_init__(self):
        if self.applied is None:
            self.applied = np.zeros(self.log.n_records, dtype=bool)


class ReplicaApplier:
    """Folds shipped chunks into ``table`` with a carried SSN high-water mark."""

    def __init__(self, table: ArrayTable, mode: str = "vectorized"):
        if mode not in ("vectorized", "pallas", "scalar"):
            raise ValueError(f"unknown apply mode {mode!r}")
        self.table = table
        self.mode = mode
        self.pending: List[_Chunk] = []
        self.n_applied = 0
        self.n_rounds = 0
        # telemetry for the RAW-safety invariant: the largest HAS_READS SSN
        # ever applied — never exceeds the watermark it was applied under,
        # except for gate-decided cross-shard records, whose RAW safety is
        # established per participant edge by the sharded cut instead
        self.max_qwr_applied = 0
        # shard id stamped on trace spans (set by the sharded replica)
        self.trace_shard = 0

    def held(self) -> int:
        """Shipped-but-unapplied records (beyond the watermark / gated out)."""
        return sum(int((~c.applied).sum()) for c in self.pending)

    def prune_below(self, ssn: int) -> int:
        """Mark every pending record with ``log.ssn <= ssn`` applied without
        folding it — the truncation-rebase path, where a freshly seeded
        checkpoint image already reflects those records (the safe-point rule
        bounds every truncated record by the checkpoint RSN, and the image
        wins the per-key SSN guard against them).  Returns records pruned.
        """
        n = 0
        for c in self.pending:
            m = ~c.applied & (c.log.ssn <= ssn)
            k = int(m.sum())
            if k:
                c.applied |= m
                n += k
        self.pending = [c for c in self.pending if not c.applied.all()]
        self.n_applied += n
        return n

    def pending_x_min_ssn(self) -> Optional[int]:
        """Smallest SSN of an unapplied cross-shard record, or None.

        The sharded replica caps its per-shard apply watermark here: a Qwr
        record must not become visible past an undecided cross-shard record
        below it (its RAW predecessor may be exactly that record, committed
        on the primary but not yet shipped on every participant).
        """
        lo: Optional[int] = None
        for c in self.pending:
            if c.log.x_rec is None:
                continue
            un = c.log.x_rec[~c.applied[c.log.x_rec]]
            if len(un):
                m = int(c.log.ssn[un].min())
                lo = m if lo is None else min(lo, m)
        return lo

    def apply(
        self,
        new_logs: Sequence[Optional[ColumnarLog]],
        watermark: int,
        gate: Optional[GateFn] = None,
    ) -> int:
        """One apply round: enqueue ``new_logs`` chunks, apply everything the
        §5 guard (at ``watermark``) and ``gate`` admit, hold the rest.
        Returns the number of records newly applied."""
        self.n_rounds += 1
        _trace = TRACER.enabled
        if _trace:
            _t0 = time.perf_counter()
        for log in new_logs:
            if log is not None and log.n_records:
                self.pending.append(_Chunk(log))
        if not self.pending:
            return 0

        # per-chunk decision mask: §5 guard & not-yet-applied & gate
        oks: List[np.ndarray] = []
        any_ok = False
        for c in self.pending:
            ok = committed_mask(c.log, watermark) & ~c.applied
            if gate is not None:
                g = gate(c.log)
                if g is not None:
                    ok &= g
                    if c.log.x_rec is not None:
                        # the gate's per-edge cut rule fully decides
                        # cross-shard records (it subsumes the local §5
                        # guard on every participant incl. this one); the
                        # local watermark — capped below the oldest
                        # undecided x-record, possibly this very record —
                        # must not re-block one the cut has admitted
                        x = c.log.x_rec
                        ok[x] = g[x] & ~c.applied[x]
            oks.append(ok)
            any_ok = any_ok or bool(ok.any())

        if any_ok:
            if self.mode == "scalar":
                self._apply_scalar(oks)
            else:
                self._apply_vectorized(oks)

        newly = 0
        for c, ok in zip(self.pending, oks):
            n_ok = int(ok.sum())
            if n_ok:
                qwr = c.log.has_reads & ok
                if qwr.any():
                    self.max_qwr_applied = max(
                        self.max_qwr_applied, int(c.log.ssn[qwr].max())
                    )
                c.applied |= ok
                newly += n_ok
        self.pending = [c for c in self.pending if not c.applied.all()]
        self.n_applied += newly
        if _trace and newly:
            TRACER.record(
                ST_APPLY, shard=self.trace_shard, t0=_t0,
                t1=time.perf_counter(), n_txn=newly, aux=watermark,
            )
        return newly

    def _table_lookup(self, key: bytes):
        """Pre-image resolver for command records (adaptive logging): a
        command's dependency may have been folded in an earlier poll — then
        its pre-image is no longer in any pending chunk but lives in the
        table row, whose carried SSN high-water mark is exactly the dep SSN
        the record observed on the primary."""
        return self.table.get(key.decode("utf-8", "surrogateescape"))

    # --- vectorized / pallas -------------------------------------------------
    def _apply_vectorized(self, oks: List[np.ndarray]) -> None:
        logs = [c.log for c in self.pending]
        # all §5/gate gating already lives in ``oks`` (computed in apply());
        # neutralize replay's internal guard so it cannot re-block a
        # cross-shard record the cut admitted past the capped watermark
        data, _, _ = replay_columnar(
            logs,
            _NO_GUARD,
            base=None,
            use_kernel=(self.mode == "pallas"),
            record_mask=oks,
            dep_lookup=self._table_lookup,
        )
        if not data:
            return
        ssns = np.fromiter((s for _, s in data.values()), np.int64, len(data))
        vals = np.fromiter((v for v, _ in data.values()), object, len(data))
        # one atomic fold: the whole round's winners become visible together
        self.table.upsert_bytes(list(data.keys()), vals, ssns)

    # --- scalar oracle -------------------------------------------------------
    def _apply_scalar(self, oks: List[np.ndarray]) -> None:
        """Per-write guarded walk.  Equivalence oracle only: each write
        folds under its own mutex hold (no phantom/torn rows, but a round
        is not visibility-atomic the way the vectorized fold is), so live
        serving should use the default modes.

        Command writes (adaptive logging) cannot fold order-free: each needs
        its key's pre-image.  They are collected across the round's chunks
        and re-executed after the value walk in SSN order — by then every
        value pre-image of the round has landed, so the table row *is* the
        dependency (same shape as recovery's deferred command pass)."""
        table = self.table
        one_val = np.empty(1, dtype=object)
        cmds: List[tuple] = []   # (ssn, key, op_id, dep_ssn, param)
        for c, ok in zip(self.pending, oks):
            log = c.log
            if not len(log.wr_rec):
                continue
            lanes = np.flatnonzero(ok[log.wr_rec]).tolist()
            if log.n_command:
                from ..core.recovery import _command_dep_per_write
                wcmd = log.cmd_mask[log.wr_rec]
                dep_w = _command_dep_per_write(log) if wcmd.any() else None
                op_w = log.cmd_op_col[log.wr_rec]
            else:
                wcmd = None
            for j in lanes:
                if wcmd is not None and wcmd[j]:
                    cmds.append((
                        int(log.ssn[log.wr_rec[j]]), log.keys[j],
                        int(op_w[j]), int(dep_w[j]), log.values[j],
                    ))
                    continue
                one_val[0] = log.values[j]
                table.upsert_bytes(
                    [log.keys[j]], one_val,
                    np.asarray([log.ssn[log.wr_rec[j]]], dtype=np.int64),
                )
        if cmds:
            from ..core.command import COMMANDS
            from ..core.recovery import _exec_command_write
            cmds.sort(key=lambda t: t[0])
            staged: dict = {}
            for ssn, key, op_id, dep, param in cmds:
                _exec_command_write(
                    staged, key, ssn, op_id, dep, param, COMMANDS,
                    self._table_lookup,
                )
            for key, (val, ssn) in staged.items():
                one_val[0] = val
                table.upsert_bytes(
                    [key], one_val, np.asarray([ssn], dtype=np.int64)
                )
