"""A RAW-safe read replica over one Poplar engine's log devices.

Wires the pieces together:

* one :class:`~repro.replica.shipper.LogShipper` per log device, polled in
  parallel (no cross-device merge — the point of partially constrained
  logs);
* one :class:`~repro.replica.applier.ReplicaApplier` folding shipped chunks
  into a live :class:`~repro.db.array_table.ArrayTable`;
* the **read watermark** :meth:`Replica.visible_ssn` — the RSNe rule
  (``min`` over per-device shipped durable frontiers) driving *visibility*
  instead of crash recovery: the applier holds every HAS_READS record above
  it, so a replica read can never observe a transaction whose RAW
  predecessor has not been applied.  This is the same
  ``CommitProtocol.committable`` predicate the primary's commit stage uses
  (Qww: own-device durability; Qwr: ``ssn <= min(DSN)``), re-evaluated on
  the replica against shipped frontiers;
* **catch-up** from a fuzzy checkpoint: seed the table from
  :class:`~repro.core.checkpoint.CheckpointData` and ship the log on top —
  replay idempotence (per-key SSN guard, checkpoint wins ties via the
  strict ``>``) makes re-shipping records already reflected in the image
  harmless, so no log/checkpoint coordination is needed;
* **promotion**: :meth:`promote` drains whatever has been shipped, applies
  the recovery consistent cut to it (anything still held is exactly what
  crash recovery would skip), and returns the servable
  :class:`~repro.core.recovery.RecoveredState` — byte-identical to
  ``recover()`` over the same devices.

Runs stepped (tests call :meth:`poll` deterministically) or continuous
(:meth:`start` spawns a tailer thread), like the engines.
"""

from __future__ import annotations

import threading
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.checkpoint import load_latest_checkpoint
from ..core.par import parallel_for
from ..core.recovery import RecoveredState
from ..core.storage import StorageDevice, TruncatedLogError
from ..db.array_table import ArrayTable
from ..obs.metrics import REGISTRY
from .applier import GateFn, ReplicaApplier
from .shipper import LogShipper


class Replica:
    """Continuously replicates one engine's devices into a readable table."""

    def __init__(
        self,
        devices: Sequence[StorageDevice],
        checkpoint_dir: Optional[str] = None,
        mode: str = "vectorized",
        parallel: bool = True,
        name: str = "replica",
    ):
        self.parallel = parallel
        self.shippers = [LogShipper(d, i) for i, d in enumerate(devices)]
        self.table = ArrayTable(name=name)
        self.applier = ReplicaApplier(self.table, mode=mode)
        self.checkpoint_dir = checkpoint_dir
        self.rsns = 0
        self.n_rebases = 0
        self.promoted = False
        self._watermark = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # monotonic stamp of the last watermark advance — "lag in seconds"
        self._w_advance_t = time.monotonic()
        self._obs_names = tuple(
            f"replica.{name}.{suffix}"
            for suffix in ("visible_ssn", "lag_ssn", "lag_s",
                           "ship_backlog_bytes", "apply_backlog")
        )
        if checkpoint_dir is not None:
            ckpt = load_latest_checkpoint(checkpoint_dir, parallel=parallel)
            if ckpt is not None:
                self.rsns = ckpt.rsn
                self._seed(ckpt.data)

    def _seed(self, data) -> None:
        """Fold a checkpoint image into the table under the per-key SSN
        guard (one atomic upsert): sound both at construction and when
        re-seeding during a truncation rebase over a table that already
        holds newer applied writes."""
        if not data:
            return
        self.table.upsert_bytes(
            list(data.keys()),
            np.fromiter((v for v, _ in data.values()), object, len(data)),
            np.fromiter((s for _, s in data.values()), np.int64, len(data)),
        )

    # --- truncation re-basing ------------------------------------------------
    def _rebase(self, cause: TruncatedLogError) -> None:
        """A shipper's offset predates its device's truncation point: the
        missing bytes are gone, but the truncator's safe-point rule says the
        checkpoint that anchored the truncation covers every dropped record.
        Catch up from it instead of reading the hole: re-seed the table from
        the newest checkpoint image, then jump every lagging shipper to its
        device's base offset with the device's persisted ``truncated_ssn``
        as its new shipped-frontier floor — byte-identical, by the replay
        idempotence guard, to having shipped the dropped records themselves.
        """
        if self.checkpoint_dir is None:
            raise cause
        ckpt = load_latest_checkpoint(self.checkpoint_dir,
                                      parallel=self.parallel)
        if ckpt is None:
            raise cause
        self._seed(ckpt.data)
        self.rsns = max(self.rsns, ckpt.rsn)
        for sh in self.shippers:
            base_fn = getattr(sh.source, "base_offset", None)
            if base_fn is None:
                continue
            base = base_fn()
            if sh.consumed + len(sh._tail) < base:
                sh.rebase(base, int(getattr(sh.source, "truncated_ssn", 0)))
        # shipped-but-held records at or below the checkpoint RSN are fully
        # reflected by the image just seeded; marking them applied keeps
        # held() honest and lifts any cross-shard visibility cap they pinned
        self.applier.prune_below(ckpt.rsn)
        self.n_rebases += 1

    # --- watermark -----------------------------------------------------------
    def shipped_frontiers(self) -> List[int]:
        """Per-device shipped durable frontiers (the replicated DSNs)."""
        return [s.frontier for s in self.shippers]

    def visible_ssn(self) -> int:
        """The RAW-safe read watermark: every transaction with reads and
        ``ssn <= visible_ssn()`` is applied — the shipped prefix's RSNe.
        Monotone in polls.

        On a standalone replica no HAS_READS transaction *above* the
        watermark is applied either.  Inside a :class:`ShardedReplica` that
        upper bound holds only for ordinary records: a decided cross-shard
        HAS_READS transaction may apply above this shard's (capped)
        watermark — its RAW safety is established per participant edge by
        the live cut, not by this scalar (see `repro.replica.sharded`)."""
        return self._watermark

    # --- stepped operation ---------------------------------------------------
    def ship(self, parallel: Optional[bool] = None):
        """Poll every device shipper (in parallel threads by default);
        returns the new chunks.  A shipper that fell behind a log truncation
        re-bases from the checkpoint transparently (see :meth:`_rebase`) and
        only the *failed* shippers are re-polled: the successful ones
        already advanced their consumed offsets, so discarding their chunks
        for a whole-round retry would lose those records forever while the
        frontiers still covered them."""
        par = self.parallel if parallel is None else parallel
        out: List[Optional[object]] = [None] * len(self.shippers)
        todo = list(range(len(self.shippers)))
        for attempt in range(4):  # a concurrent truncator pass may race
            errs: List[Optional[TruncatedLogError]] = [None] * len(self.shippers)

            def _poll(j: int, idx=tuple(todo)) -> None:
                i = idx[j]
                try:
                    out[i] = self.shippers[i].poll()
                except TruncatedLogError as e:
                    errs[i] = e

            parallel_for(len(todo), _poll, par)
            todo = [i for i in range(len(self.shippers)) if errs[i] is not None]
            if not todo:
                return out
            first = next(e for e in errs if e is not None)
            if attempt == 3:
                raise first
            self._rebase(first)
        return out

    def apply(self, new, gate: Optional[GateFn] = None,
              watermark: Optional[int] = None) -> int:
        """Advance the watermark and fold pre-shipped chunks.  ``watermark``
        caps the advance — the sharded replica uses it to keep visibility
        below undecided cross-shard records."""
        fr = [s.frontier for s in self.shippers]
        w = min(fr) if fr else 0
        if watermark is not None:
            w = min(w, watermark)
        if w > self._watermark:
            self._watermark = w
            self._w_advance_t = time.monotonic()
        n = self.applier.apply(new, self._watermark, gate=gate)
        if REGISTRY.enabled:
            names = self._obs_names
            REGISTRY.gauge_set(names[0], float(self._watermark))
            # SSN lag: spread between the fastest shipped frontier and the
            # RAW-safe watermark — what the min() rule is holding back
            REGISTRY.gauge_set(
                names[1], float((max(fr) if fr else 0) - self._watermark))
            REGISTRY.gauge_set(
                names[2], time.monotonic() - self._w_advance_t)
            REGISTRY.gauge_set(names[3], float(self.lag_bytes()))
            REGISTRY.gauge_set(names[4], float(self.applier.held()))
        return n

    def poll(self, gate: Optional[GateFn] = None,
             watermark: Optional[int] = None,
             parallel: Optional[bool] = None) -> int:
        """One replication round: ship all devices, advance the watermark,
        apply everything it admits.  Returns records newly applied."""
        return self.apply(self.ship(parallel=parallel), gate=gate,
                          watermark=watermark)

    def lag_bytes(self) -> int:
        return sum(s.lag_bytes() for s in self.shippers)

    def held(self) -> int:
        return self.applier.held()

    # --- reads ---------------------------------------------------------------
    def read(self, key: str) -> Optional[Tuple[bytes, int]]:
        """(value, ssn) as of the current watermark, or None.  RAW-safe by
        construction — the applier never folds a HAS_READS record whose
        predecessors could be missing — and torn-pair-safe: the table mutex
        makes the (value, ssn) pair atomic against a concurrent apply
        (``ArrayTable.get`` alone is lockless)."""
        with self.table.mutex:
            return self.table.get(key)

    # --- continuous operation ------------------------------------------------
    def start(self, poll_interval: float = 1e-3) -> None:
        """Tail continuously from a background thread until :meth:`stop`.

        The loop polls the devices *sequentially* — spawning a thread per
        device per poll would churn thread create/teardown thousands of
        times a second against the primary's GIL for reads that are plain
        byte copies."""
        self._stop.clear()

        def _loop() -> None:
            while not self._stop.is_set():
                if self.poll(parallel=False) == 0:
                    time.sleep(poll_interval)

        self._thread = threading.Thread(target=_loop, daemon=True,
                                        name=f"replica-{self.table.name}")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None

    # --- promotion -----------------------------------------------------------
    def drain(self, gate: Optional[GateFn] = None,
              watermark: Optional[int] = None) -> None:
        """Ship+apply until a full round makes no progress (primary dead or
        quiesced)."""
        while True:
            before = [s.consumed for s in self.shippers]
            applied = self.poll(gate=gate, watermark=watermark)
            if applied == 0 and [s.consumed for s in self.shippers] == before:
                return

    def promote(self) -> RecoveredState:
        """Turn the replica into a servable primary state: drain whatever is
        still shippable, then run the recovery consistent cut on it — the
        records still held (HAS_READS above the final RSNe) are exactly the
        durable-but-uncommitted ones crash recovery skips.  The result is
        byte-identical to ``recover(devices)`` over the same device state.
        """
        self.stop()
        self.drain()
        self.promoted = True
        return RecoveredState(
            data=self.table.to_dict(),
            rsns=self.rsns,
            rsne=self._watermark,
            n_replayed=self.applier.n_applied,
            n_skipped_uncommitted=self.applier.held(),
        )
