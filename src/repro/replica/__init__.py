"""Replication: parallel log shipping + continuous vectorized apply +
RAW-safe read replicas.

The same partially constrained per-device logs that guarantee crash
recoverability (paper §3–§5) are sufficient to feed a *live* replica — no
cross-device merge, no total order, no extra metadata:

* :class:`~repro.replica.shipper.LogShipper` — tails one log device
  incrementally (``StorageDevice.read_from``) with torn-tail-aware framing:
  a partial trailing record is retried, never decoded.
* :class:`~repro.replica.applier.ReplicaApplier` — folds shipped chunks
  into an :class:`~repro.db.array_table.ArrayTable` with the vectorized
  last-writer-wins replay, carried per-key SSN high-water marks, and the §5
  commit guard as a *visibility* rule (Qwr records held until the shipped
  RSNe passes them).
* :class:`~repro.replica.replica.Replica` — one engine's devices → a
  readable table with the :meth:`~repro.replica.replica.Replica.visible_ssn`
  watermark, checkpoint catch-up, and
  :meth:`~repro.replica.replica.Replica.promote` (byte-identical to
  ``recover()``).
* :class:`~repro.replica.sharded.ShardedReplica` — one pipeline per shard
  plus the cross-shard consistent cut applied continuously
  (``FLAG_XSHARD`` records visible only when shipped-durable from every
  participant); promotes byte-identically to ``recover_sharded()``.
"""

from .applier import ReplicaApplier
from .replica import Replica
from .sharded import ShardedReplica
from .shipper import FileSource, LogShipper, ship_all

__all__ = [
    "FileSource",
    "LogShipper",
    "Replica",
    "ReplicaApplier",
    "ShardedReplica",
    "ship_all",
]
