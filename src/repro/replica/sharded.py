"""Sharded replication: one shipper/applier pipeline per shard + the
cross-shard consistent cut applied *continuously*.

Each shard replicates independently with the single-engine machinery
(`repro.replica.replica.Replica` — per-device shippers, vectorized applier,
per-shard watermark).  Cross-shard (``FLAG_XSHARD``) records get the PR-3
cut rule as a live gate instead of a crash-time decision:

* a cross-shard record becomes applicable only once a record with its gtid
  has been **shipped from every participant** (shipped ⇒ durable ⇒ the
  global commit is inevitable), and — when it has reads — once its per-shard
  SSN clears every participant's shipped frontier *and* no other unapplied
  cross-shard record sits below it on any participant (the Qwr rule per
  edge, `repro.shard.recovery.resolve_cut`, evaluated in per-shard SSN
  order; prepare-order serialization on shared shards makes that ordering
  acyclic, so it cannot deadlock);
* until then it is *held*, and — the RAW-safety refinement live shipping
  needs on top of the crash-time cut — each shard's visibility watermark
  for ordinary HAS_READS records is **capped below its oldest unapplied
  cross-shard record**: a later HAS_READS record's RAW predecessor may be
  exactly that in-flight cross-shard transaction (committed on the
  primary, not yet shipped from every participant), so nothing with reads
  may become visible past it.  Frontiers only grow, so the cap only rises
  and every held record eventually applies (on a live primary every
  prepared participant record eventually flushes and ships).

:meth:`ShardedReplica.promote` finalizes exactly like sharded crash
recovery: whatever is still not durable-on-all-participants at the final
frontiers is dropped by ``resolve_cut`` — the promoted per-shard states are
byte-identical to ``recover_sharded()`` on the same devices.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from ..core.recovery import RecoveredState
from ..core.storage import StorageDevice
from ..core.txn import ColumnarLog
from ..shard.recovery import ShardedRecoveredState, resolve_cut
from ..shard.router import Router
from .replica import Replica


class ShardedReplica:
    """N per-shard replication pipelines + the live cross-shard cut.

    ``shard_devices[p]`` must be shard ``p``'s device list in engine shard
    order (xdep shard ids index into it), like ``recover_sharded``.
    """

    def __init__(
        self,
        shard_devices: Sequence[Sequence[StorageDevice]],
        checkpoint_dirs: Optional[Sequence[Optional[str]]] = None,
        mode: str = "vectorized",
        parallel: bool = True,
    ):
        n = len(shard_devices)
        if checkpoint_dirs is not None:
            assert len(checkpoint_dirs) == n
        self.replicas = [
            Replica(
                shard_devices[p],
                checkpoint_dir=None if checkpoint_dirs is None else checkpoint_dirs[p],
                mode=mode,
                parallel=parallel,
                name=f"replica-shard{p}",
            )
            for p in range(n)
        ]
        self.router = Router(n)
        self.promoted = False
        # cross-shard registry, accumulated from shipped chunks: gtid ->
        # participants seen durable, and gtid -> (participant vector, reads?).
        # Entries are pruned as soon as their transaction is applied (an
        # applied gtid can never be re-decided), so per-poll cut work is
        # O(in-flight cross-shard txns), not O(lifetime).
        self._durable: Dict[int, Set[int]] = {}
        self._info: Dict[int, Tuple[List[Tuple[int, int]], bool]] = {}
        self._seen_x = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # --- cross-shard registry ------------------------------------------------
    def _ingest(self, p: int, log: ColumnarLog) -> None:
        if log.x_rec is None:
            return
        for i, rec in enumerate(log.x_rec.tolist()):
            g = int(log.tid[rec])
            self._durable.setdefault(g, set()).add(p)
            if g not in self._info:
                lo, hi = int(log.xp_start[i]), int(log.xp_start[i + 1])
                self._info[g] = (
                    list(zip(log.xp_shard[lo:hi].tolist(),
                             log.xp_ssn[lo:hi].tolist())),
                    bool(log.has_reads[rec]),
                )
                self._seen_x += 1

    @staticmethod
    def _gate_for(keep: Dict[int, bool]):
        def gate(log: ColumnarLog) -> Optional[np.ndarray]:
            if log.x_rec is None:
                return None
            m = np.ones(log.n_records, dtype=bool)
            for rec in log.x_rec.tolist():
                # a gtid absent from ``keep`` was pruned after being applied
                # — the applier's per-chunk applied mask already blocks it,
                # so True is the safe default
                m[rec] = keep.get(int(log.tid[rec]), True)
            return m

        return gate

    # --- replication rounds --------------------------------------------------
    def _round(self, final: bool = False,
               parallel: Optional[bool] = None) -> Tuple[int, bool]:
        """Ship every shard, re-evaluate the cut, apply.  ``final`` switches
        the live hold-back discipline to the crash-time cut (primary dead:
        undecided cross-shard records are dropped, the watermark cap lifts).
        Returns ``(records applied, anything new shipped)``."""
        new = [r.ship(parallel=parallel) for r in self.replicas]
        shipped = any(log is not None for logs in new for log in logs)
        for p, logs in enumerate(new):
            for log in logs:
                if log is not None:
                    self._ingest(p, log)
        # checkpoint-image coverage (seeded at construction or by a
        # truncation rebase inside ship()): a record with ssn <= the shard's
        # seeded RSN is fully reflected by that image, so it needs no fold —
        # and a cross-shard record whose *every* participant edge is
        # image-covered can never be re-decided (all its records were
        # durable before the checkpoints — see the truncator's coverage
        # rule), so its registry entry is dead.  Without this, a gtid whose
        # copy was truncated away on one participant would sit undecided
        # forever, capping that shard's Qwr visibility below it.
        for r in self.replicas:
            if r.rsns:
                r.applier.prune_below(r.rsns)
        for g in list(self._info):
            parts, _ = self._info[g]
            if all(s <= self.replicas[q].rsns for q, s in parts):
                del self._info[g]
                self._durable.pop(g, None)
        frontiers = [
            min(f) if (f := r.shipped_frontiers()) else 0 for r in self.replicas
        ]
        if final:
            marks = decide = frontiers
        else:
            xmin: List[Optional[int]] = []
            for p, r in enumerate(self.replicas):
                m = r.applier.pending_x_min_ssn()
                for log in new[p]:
                    if log is not None and log.x_rec is not None and len(log.x_rec):
                        mm = int(log.ssn[log.x_rec].min())
                        m = mm if m is None else min(m, mm)
                xmin.append(m)
            # non-x Qwr visibility is capped *below* the oldest unapplied
            # x-record (its RAW predecessor may be exactly that record) ...
            marks = [f if m is None else min(f, m - 1)
                     for f, m in zip(frontiers, xmin)]
            # ... while an x-record itself is decided against the uncapped
            # shipped frontiers — but only the lowest unapplied x-record on
            # each participant may go first (no possibly-RAW-predecessor
            # x-record below it).  ``min(f, m)`` admits exactly the record
            # sitting at the minimum and everything the frontier covers;
            # prepare-order serialization on shared shards makes this
            # ordering acyclic, so every decidable record eventually applies.
            decide = [f if m is None else min(f, m)
                      for f, m in zip(frontiers, xmin)]
        keep = resolve_cut(self._durable, self._info, decide)
        gate = self._gate_for(keep)
        applied = sum(
            r.apply(new[p], gate=gate, watermark=marks[p])
            for p, r in enumerate(self.replicas)
        )
        # prune applied gtids: keep=True required durable-on-all, so every
        # participant's record was in pending and the gate applied it above
        for g, ok in keep.items():
            if ok:
                del self._info[g]
                del self._durable[g]
        return applied, shipped

    def poll(self) -> int:
        """One live replication round over every shard."""
        return self._round(final=False)[0]

    # --- watermark / reads ---------------------------------------------------
    def visible_ssn(self, shard: Optional[int] = None):
        """Per-shard RAW-safe read watermark (list without ``shard``)."""
        if shard is not None:
            return self.replicas[shard].visible_ssn()
        return [r.visible_ssn() for r in self.replicas]

    def read(self, key: str) -> Optional[Tuple[bytes, int]]:
        return self.replicas[self.router.shard_of(key)].read(key)

    def lag_bytes(self) -> int:
        return sum(r.lag_bytes() for r in self.replicas)

    def held(self) -> int:
        return sum(r.held() for r in self.replicas)

    # --- continuous operation ------------------------------------------------
    def start(self, poll_interval: float = 1e-3) -> None:
        """Continuous tailing thread; polls sequentially (see
        :meth:`Replica.start` for why not a thread per device per poll)."""
        self._stop.clear()

        def _loop() -> None:
            while not self._stop.is_set():
                if self._round(final=False, parallel=False)[0] == 0:
                    time.sleep(poll_interval)

        self._thread = threading.Thread(target=_loop, daemon=True,
                                        name="sharded-replica")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None

    # --- promotion -----------------------------------------------------------
    def promote(self) -> ShardedRecoveredState:
        """Finalize into a servable sharded state (call once the primary is
        dead/quiesced): drain everything shippable, then apply the crash
        consistent cut — byte-identical to ``recover_sharded()`` on the same
        devices."""
        self.stop()
        while True:
            applied, shipped = self._round(final=True)
            if applied == 0 and not shipped:
                break
        frontiers = [
            min(f) if (f := r.shipped_frontiers()) else 0 for r in self.replicas
        ]
        # the registry now holds only never-applied gtids: exactly the drops
        keep = resolve_cut(self._durable, self._info, frontiers)
        out = ShardedRecoveredState(
            n_cross_seen=self._seen_x,
            n_cross_dropped=sum(1 for v in keep.values() if not v),
        )
        for r in self.replicas:
            out.shards.append(
                RecoveredState(
                    data=r.table.to_dict(),
                    rsns=r.rsns,
                    rsne=r.visible_ssn(),
                    n_replayed=r.applier.n_applied,
                    n_skipped_uncommitted=r.applier.held(),
                )
            )
        self.promoted = True
        return out
