"""Deterministic synthetic token pipeline with a resumable cursor.

The stream is a seeded PRNG over the vocab with a light Markov flavour (so
the LM loss actually decreases); ``cursor`` is the number of batches already
emitted.  The cursor is part of the journaled train state: restart resumes
the stream exactly where the crashed run stopped — no repeated or skipped
batches (exactly-once data semantics via the Poplar journal).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 1234


class TokenPipeline:
    def __init__(self, cfg: DataConfig, cursor: int = 0):
        self.cfg = cfg
        self.cursor = int(cursor)

    def _batch_at(self, idx: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ idx)
        # markov-ish stream: tokens correlate with their predecessor
        base = rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len + 1), dtype=np.int64)
        carry = np.cumsum(base, axis=1) % cfg.vocab
        keep = rng.random((cfg.batch, cfg.seq_len + 1)) < 0.7
        stream = np.where(keep, carry, base).astype(np.int32)
        return {"tokens": stream[:, :-1], "labels": stream[:, 1:]}

    def next_batch(self) -> Dict[str, np.ndarray]:
        b = self._batch_at(self.cursor)
        self.cursor += 1
        return b

    def state(self) -> Dict[str, np.ndarray]:
        return {"cursor": np.asarray(self.cursor, np.int64)}

    @staticmethod
    def restore(cfg: DataConfig, state: Dict[str, np.ndarray]) -> "TokenPipeline":
        return TokenPipeline(cfg, cursor=int(state["cursor"]))
